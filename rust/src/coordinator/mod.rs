//! The serving coordinator: the "deploy the model which the DL-compiler
//! can invoke while compiling" half of the paper, built like a production
//! inference router — per-target variant *families* behind a routing
//! tier, dynamic batching, a sharded single-flight prediction cache,
//! metrics, and a line-protocol TCP front end.
//!
//! The request path is built for the paper's traffic shape (thousands of
//! concurrent, heavily duplicated queries from autotuning probes):
//!
//! - [`Service::predict`] — one query: token-length memo probe → variant
//!   routing → text-level encode memo probe (a duplicate query skips the
//!   front end entirely) → zero-copy parse → fused id-direct encode →
//!   sharded cache lookup → single-flight (duplicate concurrent misses
//!   coalesce onto one model invocation) → batch queue → PJRT.
//! - [`Service::predict_many`] — the batch API: routes and encodes all
//!   inputs, partitions into cache hits / coalesced followers / misses,
//!   and submits each variant's misses to that variant's
//!   [`batcher::BatchQueue`] in one shot (the batch is partitioned per
//!   chosen variant; rows still come back in input order).
//!
//! A target is served not by one model but by every variant registered
//! for it ([`Service::start_variants`], `--variants` on the CLI): e.g. a
//! `max_len=128` FC model next to a `max_len=512` conv stack. The
//! [`router`] picks, per query, the cheapest variant whose `max_len`
//! covers the query's token count, and honors an optional per-request
//! `budget_us` by rerouting to a faster variant — a larger covering
//! sibling when one fits the budget, else a smaller/truncating one —
//! when the preferred variant's observed latency EWMA would blow the
//! budget (see the [`router`] module docs for the exact rule). A query longer than every
//! variant is a clean error, not a silent truncation. Routing decisions
//! are observable: `routed_by_variant`, `budget_downgrades`,
//! `no_covering_variant`, and the per-variant `variants` object in the
//! `stats` command.
//!
//! On the compute side each variant runs a *pool* of workers
//! (`--workers-per-head`) draining that variant's shared queue — a slow
//! PJRT call no longer head-of-line-blocks its variant — and every
//! worker compiles the full *ladder* of predict batch sizes from the
//! manifest (e.g. b=1/8/32), running each drained chunk on the smallest
//! rung that covers it so small flushes stop paying for
//! `max_batch`-sized padding (watch `exec_by_batch` / `padded_slots` in
//! the stats).
//!
//! With a [`crate::cluster::Cluster`] attached ([`Service::set_cluster`],
//! `--peers`/`--node-id` on the CLI), the cache tier spans processes: a
//! consistent-hash ring assigns every cache key an owner node, a local
//! miss on a remote-owned key probes the owner's cache before computing,
//! and computed values are written back to the owner asynchronously — so
//! a duplicated probe is computed once per *cluster*, not once per node.
//! Peer IO runs entirely on the peer pool's worker threads; a Down owner
//! degrades the key to local compute + local cache (counted, never an
//! error).
//!
//! Python is never here: predictions run through the AOT-compiled HLO
//! executables via PJRT.

pub mod batcher;
pub mod cache;
pub mod frontend;
pub mod offload;
pub mod router;
pub mod server;
pub mod session;
pub mod stats;

use crate::bundle::Bundle;
use crate::cluster::{Cluster, PeerReply};
use crate::mlir::{parse_function, Function};
use crate::pred::PredVec;
use crate::runtime::{Executable, Manifest, Runtime, Tensor};
use crate::sim::Target;
use crate::tokenizer::span::{self, IdSpan};
use crate::tokenizer::{token_count, Scheme};
use anyhow::{anyhow, bail, Result};
use batcher::{BatchPolicy, BatchQueue, Pending, PolicyController};
use cache::{cache_key, cache_namespace, FlightGuard, Lookup, PredictionCache};
use frontend::{CachedEncode, FrontendMemo};
use router::{LenMemo, Router, TargetRoutes, Variant, VariantSpec};
use session::{Delta, SessionLine, SessionStore};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Caller-side deadline for remote owner probes (shared across a whole
/// `predict_many` batch — the probes overlap on the peer pool). A peer
/// slower than this is treated as failed for the query at hand (degrade
/// to local compute). The peer workers' socket IO timeout
/// ([`crate::cluster::peer::PEER_IO_TIMEOUT`]) is aligned with this
/// value, so a chronically slow peer fails *worker-side* too, its health
/// flips Down after a few strikes, and subsequent probes fail fast
/// without waiting — the serving thread's worst sustained stall is a few
/// strikes' worth, not one deadline per query forever. (With
/// `--request-workers ≥ 1` the wait is parked on an [`offload`] pool
/// worker, never an IO thread — the IO loop keeps serving its other
/// connections while this deadline runs.)
const REMOTE_GET_TIMEOUT: Duration = Duration::from_millis(500);

/// Compute-side knobs for [`Service::start_with`] /
/// [`Service::start_variants`] (the front end's knobs live on
/// [`server::ServerConfig`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Select the Pallas-kernel predict executables for conv models.
    pub use_pallas: bool,
    /// Workers draining each variant's shared batch queue (the CLI flag
    /// kept its historical `--workers-per-head` name). More than one
    /// means a slow PJRT call no longer head-of-line-blocks the
    /// variant: the next flush is picked up by an idle pool member.
    pub workers_per_head: usize,
    /// Let each variant's [`batcher::PolicyController`] retune its
    /// `max_batch`/`max_wait_us` from observed flush fill and execute
    /// latency (`--batch-policy adaptive`). Off = the startup policy is
    /// pinned, exactly the pre-adaptive behavior.
    pub adaptive_batch: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { use_pallas: false, workers_per_head: 1, adaptive_batch: false }
    }
}

/// Entries the text-level encode memo holds (~2 KB per entry at
/// max_len 512; ids are shared, not duplicated, on hit).
const FRONTEND_MEMO_CAPACITY: usize = 8192;

/// One routed prediction: the full characteristic vector from ONE
/// forward pass, plus which registered variant served it (surfaced on
/// the wire as the response's `variant` field). `targets` names the
/// vector's slots in declared order — `value.get(i)` is the prediction
/// for `targets[i]`, and `value.first()` is the primary target's value
/// (the scalar the legacy `prediction` wire field carries).
#[derive(Debug, Clone)]
pub struct RoutedPrediction {
    pub value: PredVec,
    pub targets: Vec<Target>,
    pub variant: Arc<str>,
}

impl RoutedPrediction {
    /// The prediction for one named characteristic, if this variant
    /// serves it.
    pub fn value_for(&self, target: Target) -> Option<f64> {
        self.targets.iter().position(|&t| t == target).and_then(|i| self.value.get(i))
    }
}

/// The cost-model service a DL-compiler connects to.
pub struct Service {
    /// Per-target variant tables + token-length memo: every query goes
    /// through here to pick its serving variant.
    router: Router,
    pub cache: Arc<PredictionCache>,
    pub stats: Arc<stats::ServiceStats>,
    /// `hash(target, variant, model, mlir_text)` → `(ids, cache_key)`:
    /// duplicate probes skip parse/tokenize/encode entirely.
    memo: FrontendMemo,
    /// The incremental tier's registered base texts
    /// ([`Service::session_open`] / [`Service::predict_delta`]):
    /// near-duplicate probes re-lex only their changed lines.
    sessions: SessionStore,
    /// The cluster tier, when this node is one of several sharing one
    /// logical cache ([`Service::set_cluster`]). `None` = single node.
    cluster: Option<Arc<Cluster>>,
}

/// What [`Service::session_open`] returns: the registered session's id,
/// the base text's unpadded token count, and the base prediction (the
/// open doubles as a normal query).
#[derive(Debug)]
pub struct SessionOpened {
    pub session_id: u64,
    pub token_len: usize,
    pub prediction: RoutedPrediction,
}

/// One [`Service::predict_delta`] answer: the routed prediction plus
/// this request's incremental-tier accounting (how many line spans were
/// spliced from cache vs re-lexed).
#[derive(Debug)]
pub struct DeltaOutcome {
    pub prediction: RoutedPrediction,
    pub token_len: usize,
    pub spans_spliced: u64,
    pub spans_reencoded: u64,
}

/// Every point-in-time gauge `stats_json` reports, read back to back at
/// one instant — see [`Service::gauge_snapshot`].
struct GaugeSnapshot {
    cache_entries: usize,
    frontend_memo_entries: usize,
    len_memo_entries: usize,
    sessions_open: u64,
    offload_queue_depth: u64,
}

impl Service {
    /// Spin up one single-worker variant per bundle (each named after
    /// its model). `use_pallas` selects the Pallas-kernel predict
    /// executables for conv models. See [`Service::start_with`] for
    /// worker pools and [`Service::start_variants`] for multi-variant
    /// targets.
    pub fn start(
        manifest: Arc<Manifest>,
        bundles: Vec<Bundle>,
        policy: BatchPolicy,
        use_pallas: bool,
    ) -> Result<Service> {
        let opts = ServeOptions { use_pallas, ..ServeOptions::default() };
        Service::start_with(manifest, bundles, policy, opts)
    }

    /// Spin up `opts.workers_per_head` workers per bundle, each bundle
    /// becoming the sole variant of its target (named after its model).
    pub fn start_with(
        manifest: Arc<Manifest>,
        bundles: Vec<Bundle>,
        policy: BatchPolicy,
        opts: ServeOptions,
    ) -> Result<Service> {
        let specs = bundles
            .into_iter()
            .map(|bundle| VariantSpec { name: bundle.model.clone(), bundle })
            .collect();
        Service::start_variants(manifest, specs, policy, opts)
    }

    /// Spin up every registered variant: a target may be served by
    /// several (e.g. a `max_len=128` FC model next to a `max_len=512`
    /// conv stack), and the [`router`] picks one per query by token
    /// length and optional latency budget. Variant names must be unique
    /// within a target, and a target's variants must share a
    /// tokenization scheme (the routing length is measured once per
    /// text).
    ///
    /// Each worker owns its own PJRT client: the `xla` crate's handles
    /// are deliberately `!Send` (non-atomic refcounts around the C API),
    /// so the full executable ladder is compiled inside the worker
    /// thread it serves from.
    pub fn start_variants(
        manifest: Arc<Manifest>,
        specs: Vec<VariantSpec>,
        policy: BatchPolicy,
        opts: ServeOptions,
    ) -> Result<Service> {
        // Reject an invalid variant set BEFORE spawning anything: a
        // failed startup must not leave worker pools parked on orphaned
        // queues.
        router::validate_variant_set(
            specs.iter().map(|s| (s.bundle.primary_target(), s.name.as_str(), s.bundle.scheme)),
        )?;
        let cache = Arc::new(PredictionCache::new(65536));
        let stats = Arc::new(stats::ServiceStats::default());
        let pool = opts.workers_per_head.max(1);
        // Pass 1 (fallible): resolve every variant's executable ladder.
        // Nothing has been spawned yet, so a bad spec anywhere in the
        // set is a clean error — no worker pools left parked on queues
        // nobody will ever close.
        let mut planned: Vec<(Bundle, String, Vec<(PathBuf, usize)>)> = Vec::new();
        for spec in specs {
            let bundle = spec.bundle;
            let mm = manifest.model(&bundle.model)?;
            // The full batch-size ladder, with the per-rung pallas
            // fallback (non-conv models have no pallas variants).
            let mut ladder: Vec<(PathBuf, usize)> = Vec::new();
            for (key, batch) in mm.predict_ladder(policy.max_batch, opts.use_pallas) {
                let key = if opts.use_pallas && mm.files.get(&key).is_none() {
                    format!("predict_b{batch}")
                } else {
                    key
                };
                ladder.push((manifest.path_of(mm.file(&key)?), batch));
            }
            planned.push((bundle, spec.name, ladder));
        }
        // Pass 2 (infallible): spawn the worker pools.
        let mut variants: Vec<(Target, Variant)> = Vec::new();
        for (bundle, name, ladder) in planned {
            let queue = BatchQueue::new(policy.clone());
            // Shared with the pool: workers observe each completed
            // request's queue-wait + execute span into both estimators
            // (EWMA for back-compat/cold-start, P² sketch for the p95
            // the budget router actually reads).
            let ewma_us = Arc::new(stats::LatencyEwma::default());
            let p95_us = Arc::new(stats::QuantileSketch::new(0.95));
            // The per-variant batch policy: bounds are derived from the
            // startup policy before anything can retune it.
            let policy_ctl = PolicyController::new(queue.clone(), opts.adaptive_batch);
            // Only the LAST pool member to fail startup may close the
            // queue — while any worker lives, the variant keeps serving.
            let live = Arc::new(AtomicUsize::new(pool));
            let workers = (0..pool)
                .map(|_| {
                    spawn_worker(
                        ladder.clone(),
                        bundle.params.clone(),
                        bundle.max_len,
                        bundle.n_targets(),
                        queue.clone(),
                        stats.clone(),
                        ewma_us.clone(),
                        p95_us.clone(),
                        policy_ctl.clone(),
                        live.clone(),
                    )
                })
                .collect();
            let group = bundle.primary_target();
            let cache_ns = cache_namespace(group.name(), &name, &bundle.model);
            variants.push((
                group,
                Variant {
                    name: Arc::from(name.as_str()),
                    bundle,
                    cache_ns,
                    queue,
                    workers,
                    routed: AtomicU64::new(0),
                    budget_downgrades: AtomicU64::new(0),
                    ewma_us,
                    p95_us,
                    policy: policy_ctl,
                    span_table: frontend::ShardedMemo::with_shards(
                        router::SPAN_TABLE_CAPACITY,
                        router::SPAN_TABLE_SHARDS,
                    ),
                },
            ));
        }
        // The set was validated before anything spawned, so this
        // re-check cannot fail.
        Ok(Service {
            router: Router::build(variants)?,
            cache,
            stats,
            memo: FrontendMemo::new(FRONTEND_MEMO_CAPACITY),
            sessions: SessionStore::new(session::SESSIONS_CAPACITY),
            cluster: None,
        })
    }

    /// Attach the cluster tier (before the service starts taking
    /// traffic): remote-owned cache keys are looked up at — and written
    /// back to — their consistent-hash owner node from here on.
    pub fn set_cluster(&mut self, cluster: Arc<Cluster>) {
        self.cluster = Some(cluster);
    }

    /// The attached cluster, if any (tests and stats use this).
    pub fn cluster(&self) -> Option<&Arc<Cluster>> {
        self.cluster.as_ref()
    }

    pub fn targets(&self) -> Vec<Target> {
        self.router.targets()
    }

    /// The registered variant names for a target, in routing order
    /// (`max_len` ascending).
    pub fn variant_names(&self, target: Target) -> Result<Vec<String>> {
        Ok(self.router.routes(target)?.variants.iter().map(|v| v.name.to_string()).collect())
    }

    /// Warm-start (or pin, in tests) a variant's latency estimate — the
    /// EWMA that `budget_us` routing decisions read. Useful at startup
    /// when historical latencies are known: a cold EWMA reads 0.0 and
    /// will never be budget-downgraded away from until real samples
    /// arrive.
    pub fn set_variant_ewma_us(&self, target: Target, variant: &str, us: f64) -> Result<()> {
        let tr = self.router.routes(target)?;
        let v = tr
            .find(variant)
            .ok_or_else(|| anyhow!("no variant '{variant}' for target '{}'", target.name()))?;
        v.ewma_us.set(us);
        Ok(())
    }

    /// Warm-start a variant's live batch policy from known-good values
    /// (the variants manifest's `policy` keys): either knob may be
    /// omitted to keep its startup value, and both are clamped to the
    /// controller's bounds — a manifest can never push a variant outside
    /// what `--max-batch`/`--max-wait-us` configured.
    pub fn set_variant_policy(
        &self,
        target: Target,
        variant: &str,
        max_batch: Option<usize>,
        max_wait_us: Option<u64>,
    ) -> Result<()> {
        let tr = self.router.routes(target)?;
        let v = tr
            .find(variant)
            .ok_or_else(|| anyhow!("no variant '{variant}' for target '{}'", target.name()))?;
        v.policy.warm_start(max_batch, max_wait_us);
        Ok(())
    }

    /// Route one query: measure its token length (memoized per text),
    /// pick a variant by length + optional budget + required
    /// characteristic coverage, and produce that variant's encoding
    /// (memoized per (variant, text)). Returns the chosen variant's
    /// index into `tr.variants` plus the encoding. Parse failures are
    /// not memoized — the error path is not the hot path.
    ///
    /// `required` lists the characteristics the caller needs in the
    /// answer: a variant whose bundle does not serve ALL of them is
    /// invisible to routing, and when no variant covers the set the
    /// query fails with a clean `targets_not_served` error — never a
    /// silent partial answer.
    fn route_on(
        &self,
        tr: &TargetRoutes,
        target: Target,
        mlir_text: &str,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Result<(usize, CachedEncode)> {
        let t0 = Instant::now();
        // ONE full-text hash per query; both memo keys derive from it.
        let text_hash = FrontendMemo::text_hash(mlir_text);
        // Step 1: the query's unpadded token length — one memo probe on
        // duplicates, one counting tokenizer pass on first sight. The
        // parsed function is kept for step 3 so a brand-new text parses
        // once, not twice.
        let len_key = LenMemo::key_from_hash(target.name(), text_hash);
        let mut parsed: Option<Function> = None;
        let token_len = match self.router.len_memo.get(len_key) {
            Some(n) => n,
            None => {
                let func = parse_function(mlir_text)?;
                let n = token_count(&func, tr.scheme);
                self.router.len_memo.insert(len_key, n);
                parsed = Some(func);
                n
            }
        };
        // Step 2: the routing decision.
        let vidx = self.choose_on(tr, target, token_len, budget_us, required)?;
        let variant = &tr.variants[vidx];
        // Step 3: the chosen variant's encoding, memoized per
        // (target, variant, model, text) so variants never cross-serve
        // each other's id rows.
        let text_key = FrontendMemo::key_from_hash(
            target.name(),
            &variant.name,
            &variant.bundle.model,
            text_hash,
        );
        if let Some(enc) = self.memo.get(text_key) {
            self.stats.frontend_memo_hits.fetch_add(1, Ordering::Relaxed);
            self.stats.encode_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            return Ok((vidx, enc));
        }
        let func = match parsed.take() {
            Some(f) => f,
            None => parse_function(mlir_text)?,
        };
        let (ids, _oov) = variant.bundle.encode_ids(&func);
        let key = cache_key(&variant.cache_ns, &ids);
        let enc = CachedEncode { ids: Arc::new(ids), key };
        self.memo.insert(text_key, enc.clone());
        self.stats.encode_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok((vidx, enc))
    }

    /// The routing decision proper, shared by the full-text path
    /// ([`Service::route_on`], which tokenizes to learn `token_len`) and
    /// the session tier (which *sums cached per-line counts* instead):
    /// pick a variant by token length + optional budget + required
    /// coverage, bump the routing counters, or refuse cleanly.
    fn choose_on(
        &self,
        tr: &TargetRoutes,
        target: Target,
        token_len: usize,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Result<usize> {
        let Some((vidx, downgraded)) = tr.choose(token_len, budget_us, required) else {
            // Two distinct refusals: nothing covers the token length
            // (the pre-multi-output error, message unchanged), or the
            // length is covered but no eligible variant serves every
            // requested characteristic.
            if !tr.covers_len(token_len) {
                self.stats.no_covering_variant.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "no variant of target '{}' covers token length {token_len} \
                     (largest registered max_len is {})",
                    target.name(),
                    tr.largest_max_len(),
                );
            }
            self.stats.targets_not_served.fetch_add(1, Ordering::Relaxed);
            let missing: Vec<&str> =
                tr.unserved(required).into_iter().map(|t| t.name()).collect();
            bail!(
                "targets_not_served: no variant of target '{}' serves requested \
                 characteristic(s) [{}]",
                target.name(),
                missing.join(", "),
            );
        };
        let variant = &tr.variants[vidx];
        variant.routed.fetch_add(1, Ordering::Relaxed);
        if downgraded {
            variant.budget_downgrades.fetch_add(1, Ordering::Relaxed);
            self.stats.budget_downgrades.fetch_add(1, Ordering::Relaxed);
        }
        Ok(vidx)
    }

    /// Silent warm probe for the offload classifier: would this single
    /// `mlir` query be answered from memo + cache alone? Chains the len
    /// memo, a *pure* routing choose, the frontend memo, and a cache
    /// [`PredictionCache::peek`] — no counters move, no single-flight
    /// guard is taken, nothing is inserted, so the real path still
    /// counts (and races) exactly once. Error paths (unknown target,
    /// clean routing refusal) report `true`: the error is produced
    /// inline in microseconds, no reason to offload it. The answer is
    /// advisory — a stale probe costs one misrouted line's latency,
    /// never correctness.
    pub(crate) fn probe_warm(
        &self,
        target: Target,
        mlir_text: &str,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> bool {
        let Ok(tr) = self.router.routes(target) else {
            return true; // unknown target: the error answers inline
        };
        let text_hash = FrontendMemo::text_hash(mlir_text);
        let len_key = LenMemo::key_from_hash(target.name(), text_hash);
        let Some(token_len) = self.router.len_memo.get(len_key) else {
            return false; // first sight: must tokenize ⇒ must execute
        };
        let Some((vidx, _)) = tr.choose(token_len, budget_us, required) else {
            return true; // clean refusal answers inline
        };
        let variant = &tr.variants[vidx];
        let text_key = FrontendMemo::key_from_hash(
            target.name(),
            &variant.name,
            &variant.bundle.model,
            text_hash,
        );
        let Some(enc) = self.memo.get(text_key) else {
            return false; // encoding unknown ⇒ cache key unknown
        };
        self.cache.peek(enc.key).is_some()
    }

    /// Predict the primary hardware characteristic for a raw MLIR
    /// function text (scalar back-compat surface). Routes to the
    /// cheapest covering variant (no budget); see
    /// [`Service::predict_with`] for per-request latency budgets and
    /// [`Service::predict_full`] for the whole characteristic vector.
    pub fn predict(&self, target: Target, mlir_text: &str) -> Result<f64> {
        Ok(self.predict_with(target, mlir_text, None)?.value.first())
    }

    /// [`Service::predict_full`] with no required-characteristic list:
    /// any variant of the target group may serve.
    pub fn predict_with(
        &self,
        target: Target,
        mlir_text: &str,
        budget_us: Option<u64>,
    ) -> Result<RoutedPrediction> {
        self.predict_full(target, mlir_text, budget_us, &[])
    }

    /// The full request path: token-length routing (+ optional
    /// `budget_us` downgrade + required-characteristic coverage) →
    /// memoized front end (zero-copy parse + fused id-direct encode on
    /// first sight, one hash + one lookup on duplicates) → sharded
    /// cache (single-flight) → batch → PJRT → denormalize. A warm
    /// repeat of the same text allocates no `String` anywhere on this
    /// path. The returned [`RoutedPrediction`] carries every
    /// characteristic the serving variant declares — all produced by
    /// ONE forward pass — and names the variant that served the query.
    pub fn predict_full(
        &self,
        target: Target,
        mlir_text: &str,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Result<RoutedPrediction> {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let tr = self.router.routes(target)?;
        let (vidx, enc) = self.route_on(tr, target, mlir_text, budget_us, required)?;
        let variant = &tr.variants[vidx];
        let value = self.serve_encoded(variant, &enc)?;
        self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
        Ok(RoutedPrediction {
            value,
            targets: variant.bundle.targets.clone(),
            variant: variant.name.clone(),
        })
    }

    /// The back half of a single query, shared by every front end (full
    /// text, session open, delta): sharded cache lookup → single-flight
    /// follower wait or leader compute.
    fn serve_encoded(&self, variant: &Variant, enc: &CachedEncode) -> Result<PredVec> {
        match self.cache.lookup(enc.key) {
            Lookup::Hit(v) => {
                self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                Ok(v)
            }
            Lookup::Wait(rx) => wait_for_leader(rx),
            Lookup::Miss(guard) => self.complete_miss(variant, enc, guard),
        }
    }

    /// Register an incremental session: index `mlir_text` line by line
    /// (per-line token counts under the target's scheme), serve the
    /// base prediction through the normal full pipeline, and — before
    /// admitting the session — prove the line tokenizer agrees with
    /// that pipeline by splicing the base from spans and comparing the
    /// id rows byte for byte. The splice pass doubles as span-table
    /// warm-up, so the first [`Service::predict_delta`] already splices
    /// every unchanged line.
    ///
    /// A text the line grammar cannot handle (anything that does not
    /// match the printer's line forms) is a clean refusal: the client
    /// keeps using plain full-text queries for it.
    pub fn session_open(
        &self,
        target: Target,
        mlir_text: &str,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Result<SessionOpened> {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let tr = self.router.routes(target)?;
        let lines = session::index_lines(mlir_text, tr.scheme)?;
        let token_len = session::indexed_token_len(&lines);
        // The full pipeline serves the base (and fills the len/encode
        // memos exactly as a cold plain query would).
        let (vidx, enc) = self.route_on(tr, target, mlir_text, budget_us, required)?;
        let variant = &tr.variants[vidx];
        // Warm the routed variant's span table and gate the session on
        // byte-identity: a spliced row that differs from the full
        // pipeline's would silently corrupt every delta after it.
        let mut spans: Vec<IdSpan> = Vec::with_capacity(lines.len());
        for line in &lines {
            let span = match variant.span_table.get(line.hash) {
                Some(s) => s,
                None => {
                    let s = span::line_span(
                        &line.text,
                        tr.scheme,
                        &variant.bundle.vocab,
                        &variant.bundle.op_ids,
                    )?;
                    variant.span_table.insert(line.hash, s.clone());
                    s
                }
            };
            spans.push(span);
        }
        let tail = span::tail_span(&variant.bundle.vocab);
        let (ids, _oov) =
            span::splice_ids(spans.iter().chain(std::iter::once(&tail)), variant.bundle.max_len);
        if ids != *enc.ids {
            bail!(
                "session_open integrity check failed for target '{}': spliced ids \
                 differ from the full pipeline (tokenizer bug, not a client error)",
                target.name(),
            );
        }
        let value = self.serve_encoded(variant, &enc)?;
        let prediction = RoutedPrediction {
            value,
            targets: variant.bundle.targets.clone(),
            variant: variant.name.clone(),
        };
        let (session_id, evicted) = self.sessions.open(
            target,
            Arc::new(mlir_text.to_string()),
            Arc::new(lines),
            token_len,
        );
        // Net gauge move: one opened, `evicted` LRU-dropped.
        self.stats.sessions_open.fetch_add(1, Ordering::Relaxed);
        if evicted > 0 {
            self.stats.sessions_open.fetch_sub(evicted as u64, Ordering::Relaxed);
        }
        self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
        Ok(SessionOpened { session_id, token_len, prediction })
    }

    /// Serve an edit against a registered session: materialize the new
    /// text (byte-range splices or full replacement), line-diff it
    /// against the base so only the changed middle is ever re-counted,
    /// route on the summed length, and assemble the id row from the
    /// routed variant's span table — re-lexing ONLY lines whose spans
    /// are not already cached. With `rebase`, the result becomes the
    /// session's new base for subsequent deltas; without it, every
    /// delta keeps addressing the originally registered text.
    pub fn predict_delta(
        &self,
        session_id: u64,
        delta: Delta,
        rebase: bool,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Result<DeltaOutcome> {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.delta_requests.fetch_add(1, Ordering::Relaxed);
        let Some(base) = self.sessions.snapshot(session_id) else {
            bail!("unknown session {session_id} (never opened, closed, or evicted)");
        };
        let tr = self.router.routes(base.target)?;
        let new_text = match delta {
            Delta::Splices(ref splices) => session::apply_splices(&base.text, splices)?,
            Delta::Full(text) => text,
        };
        let (lines, _changed) = session::reindex_lines(&base.lines, &new_text, tr.scheme)?;
        let token_len = session::indexed_token_len(&lines);
        // Same length-based decision a full query would make — but the
        // length came from cached per-line sums, not a tokenizer pass.
        let vidx = self.choose_on(tr, base.target, token_len, budget_us, required)?;
        let variant = &tr.variants[vidx];
        let (enc, spliced, reencoded) = self.encode_query(variant, tr.scheme, &lines)?;
        let value = self.serve_encoded(variant, &enc)?;
        let prediction = RoutedPrediction {
            value,
            targets: variant.bundle.targets.clone(),
            variant: variant.name.clone(),
        };
        if rebase {
            self.sessions.rebase(session_id, Arc::new(new_text), Arc::new(lines), token_len);
        }
        self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
        Ok(DeltaOutcome {
            prediction,
            token_len,
            spans_spliced: spliced,
            spans_reencoded: reencoded,
        })
    }

    /// The incremental tier's front end ([`Service::predict_delta`]'s
    /// encode step): assemble the padded id row by splicing each line's
    /// cached span out of the variant's span table, re-lexing only the
    /// misses. Returns the encoding plus this request's splice/re-lex
    /// split (also accumulated into `spans_spliced` /
    /// `spans_reencoded` / `delta_bytes_rescanned`).
    fn encode_query(
        &self,
        variant: &Variant,
        scheme: Scheme,
        lines: &[SessionLine],
    ) -> Result<(CachedEncode, u64, u64)> {
        let t0 = Instant::now();
        let mut spliced = 0u64;
        let mut reencoded = 0u64;
        let mut spans: Vec<IdSpan> = Vec::with_capacity(lines.len());
        for line in lines {
            match variant.span_table.get(line.hash) {
                Some(s) => {
                    spliced += 1;
                    spans.push(s);
                }
                None => {
                    let s = span::line_span(
                        &line.text,
                        scheme,
                        &variant.bundle.vocab,
                        &variant.bundle.op_ids,
                    )?;
                    variant.span_table.insert(line.hash, s.clone());
                    reencoded += 1;
                    self.stats
                        .delta_bytes_rescanned
                        .fetch_add(line.text.len() as u64, Ordering::Relaxed);
                    spans.push(s);
                }
            }
        }
        let tail = span::tail_span(&variant.bundle.vocab);
        let (ids, _oov) =
            span::splice_ids(spans.iter().chain(std::iter::once(&tail)), variant.bundle.max_len);
        let key = cache_key(&variant.cache_ns, &ids);
        self.stats.spans_spliced.fetch_add(spliced, Ordering::Relaxed);
        self.stats.spans_reencoded.fetch_add(reencoded, Ordering::Relaxed);
        self.stats.encode_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok((CachedEncode { ids: Arc::new(ids), key }, spliced, reencoded))
    }

    /// Drop a session (the `session_close` wire command). Returns
    /// whether the id was live — closing twice is not an error, just
    /// `false`.
    pub fn session_close(&self, session_id: u64) -> bool {
        let closed = self.sessions.close(session_id);
        if closed {
            self.stats.sessions_open.fetch_sub(1, Ordering::Relaxed);
        }
        closed
    }

    /// Resolve a genuine local-cache miss (this thread is the
    /// single-flight leader). With a cluster attached and the key owned
    /// by another node, the owner's cache is consulted first — the probe
    /// runs on the peer pool's worker threads, this thread only parks on
    /// a channel — and a locally computed value is written back to the
    /// owner asynchronously. A Down or failing owner degrades the key to
    /// local compute + local cache; peer state is never an error.
    fn complete_miss(
        &self,
        variant: &Variant,
        enc: &CachedEncode,
        guard: FlightGuard<'_>,
    ) -> Result<PredVec> {
        let owner = self.cluster.as_ref().and_then(|c| c.owner_peer(enc.key));
        let mut write_back = false;
        if let Some(peer) = owner {
            match peer.get(enc.key, REMOTE_GET_TIMEOUT) {
                None => {
                    // Down owner inside its backoff: fail fast, no probe.
                    self.stats.degraded_fallbacks.fetch_add(1, Ordering::Relaxed);
                }
                Some(reply) => {
                    self.stats.forwarded_gets.fetch_add(1, Ordering::Relaxed);
                    match reply {
                        PeerReply::Found(v) => {
                            self.stats.remote_hits.fetch_add(1, Ordering::Relaxed);
                            // Publish locally too: the local LRU absorbs
                            // repeats without re-crossing the network.
                            guard.complete(v);
                            return Ok(v);
                        }
                        PeerReply::NotFound => write_back = true,
                        PeerReply::Failed => {
                            self.stats.peer_failures.fetch_add(1, Ordering::Relaxed);
                            self.stats.degraded_fallbacks.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
        // The miss path proper. The variant's latency EWMA — the
        // estimate `budget_us` routing reads — is fed worker-side at
        // completion (per-request `submitted.elapsed()`), so it stays
        // accurate no matter how callers collect results. Cache hits
        // don't feed it: a hit costs the same on every variant.
        let rx = variant.queue.submit(enc.ids.as_ref().clone());
        let norm = rx.recv().map_err(|_| anyhow!("prediction worker gone"))?;
        let value = variant.bundle.denormalize(norm);
        guard.complete(value);
        if write_back {
            if let Some(peer) = owner {
                if peer.put(enc.key, value) {
                    self.stats.forwarded_puts.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(value)
    }

    /// Batch API: predict the primary characteristic for many MLIR
    /// texts in one call, routing each entry independently (no budget).
    /// See [`Service::predict_many_with`].
    pub fn predict_many(&self, target: Target, mlir_texts: &[&str]) -> Vec<Result<f64>> {
        self.predict_many_with(target, mlir_texts, None)
            .into_iter()
            .map(|r| r.map(|p| p.value.first()))
            .collect()
    }

    /// Batch API with routing detail: predict for many MLIR texts in one
    /// call, each entry routed independently by its own token length
    /// (one `budget_us` applies to every entry).
    ///
    /// All inputs are routed/encoded up front, partitioned into cache
    /// hits, single-flight followers (an identical query is already in
    /// flight — here or on another thread), and genuine misses; then the
    /// misses are partitioned *per chosen variant* and enter each
    /// variant's [`BatchQueue`] via one `submit_many` (one lock, one
    /// worker wakeup per variant — a batch spanning variants fans out to
    /// every variant's worker pool concurrently). Results come back in
    /// input order regardless of which variant served each row; per-input
    /// failures (malformed MLIR, uncovered length) don't fail the rest of
    /// the batch.
    pub fn predict_many_with(
        &self,
        target: Target,
        mlir_texts: &[&str],
        budget_us: Option<u64>,
    ) -> Vec<Result<RoutedPrediction>> {
        self.predict_many_full(target, mlir_texts, budget_us, &[])
    }

    /// [`Service::predict_many_with`] plus a required-characteristic
    /// list applied to every entry: each row is served by a variant
    /// covering ALL of `required`, or fails alone with a
    /// `targets_not_served` error.
    pub fn predict_many_full(
        &self,
        target: Target,
        mlir_texts: &[&str],
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Vec<Result<RoutedPrediction>> {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(mlir_texts.len() as u64, Ordering::Relaxed);
        self.stats.batch_requests.fetch_add(1, Ordering::Relaxed);
        let tr = match self.router.routes(target) {
            Ok(tr) => tr,
            Err(_) => {
                return mlir_texts
                    .iter()
                    .map(|_| Err(anyhow!("no model serving target '{}'", target.name())))
                    .collect()
            }
        };

        enum Slot<'a> {
            Done(Result<RoutedPrediction>),
            /// Remote-owned miss with an owner probe in flight.
            Probe {
                guard: FlightGuard<'a>,
                rx: std::sync::mpsc::Receiver<PeerReply>,
                enc: CachedEncode,
                vidx: usize,
            },
            /// `miss_idx` indexes into the chosen variant's miss list.
            Leader {
                guard: FlightGuard<'a>,
                vidx: usize,
                miss_idx: usize,
                write_back_key: Option<u64>,
            },
            Follower {
                rx: std::sync::mpsc::Receiver<Option<PredVec>>,
                vidx: usize,
            },
        }

        // One routed row: the variant's full characteristic vector plus
        // its declared slot names.
        let routed = |value: PredVec, vidx: usize| RoutedPrediction {
            value,
            targets: tr.variants[vidx].bundle.targets.clone(),
            variant: tr.variants[vidx].name.clone(),
        };

        // Phase 1: route + encode + partition (hits resolve
        // immediately). Misses are grouped per chosen variant. For a
        // miss whose key another node owns, the owner probe is *started*
        // here — all of a batch's remote lookups overlap instead of
        // paying one round trip each in sequence.
        let mut slots: Vec<Slot> = Vec::with_capacity(mlir_texts.len());
        let mut miss_ids: Vec<Vec<Vec<u32>>> =
            (0..tr.variants.len()).map(|_| Vec::new()).collect();
        for text in mlir_texts {
            match self.route_on(tr, target, text, budget_us, required) {
                Err(e) => slots.push(Slot::Done(Err(e))),
                Ok((vidx, enc)) => match self.cache.lookup(enc.key) {
                    Lookup::Hit(v) => {
                        self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                        slots.push(Slot::Done(Ok(routed(v, vidx))));
                    }
                    Lookup::Wait(rx) => slots.push(Slot::Follower { rx, vidx }),
                    Lookup::Miss(guard) => {
                        let owner = self.cluster.as_ref().and_then(|c| c.owner_peer(enc.key));
                        match owner.and_then(|p| p.begin_get(enc.key)) {
                            Some(rx) => {
                                self.stats.forwarded_gets.fetch_add(1, Ordering::Relaxed);
                                slots.push(Slot::Probe { guard, rx, enc, vidx });
                            }
                            None => {
                                if owner.is_some() {
                                    // Remote-owned but the owner is Down:
                                    // degrade to plain local compute.
                                    self.stats
                                        .degraded_fallbacks
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                                slots.push(Slot::Leader {
                                    guard,
                                    vidx,
                                    miss_idx: miss_ids[vidx].len(),
                                    write_back_key: None,
                                });
                                miss_ids[vidx].push(enc.ids.as_ref().clone());
                            }
                        }
                    }
                },
            }
        }

        // Phase 1.5: collect the overlapped owner probes. Remote hits
        // complete their guards (waking same-batch followers of the same
        // key); remote misses become leaders that will write back to the
        // owner; failed probes degrade to plain local leaders. ONE
        // deadline covers the whole collection phase — the probes run
        // concurrently on the peer pool, so a slot resolved while an
        // earlier one was being awaited costs nothing, and a slow peer
        // bounds the entire batch at REMOTE_GET_TIMEOUT, not N× it.
        let probe_deadline = Instant::now() + REMOTE_GET_TIMEOUT;
        for slot in slots.iter_mut() {
            if matches!(slot, Slot::Probe { .. }) {
                let placeholder = Slot::Done(Err(anyhow!("slot already taken")));
                let Slot::Probe { guard, rx, enc, vidx } = std::mem::replace(slot, placeholder)
                else {
                    unreachable!()
                };
                let remaining = probe_deadline.saturating_duration_since(Instant::now());
                let reply = rx.recv_timeout(remaining).unwrap_or(PeerReply::Failed);
                *slot = match reply {
                    PeerReply::Found(v) => {
                        self.stats.remote_hits.fetch_add(1, Ordering::Relaxed);
                        guard.complete(v);
                        Slot::Done(Ok(routed(v, vidx)))
                    }
                    PeerReply::NotFound => {
                        let next = Slot::Leader {
                            guard,
                            vidx,
                            miss_idx: miss_ids[vidx].len(),
                            write_back_key: Some(enc.key),
                        };
                        miss_ids[vidx].push(enc.ids.as_ref().clone());
                        next
                    }
                    PeerReply::Failed => {
                        self.stats.peer_failures.fetch_add(1, Ordering::Relaxed);
                        self.stats.degraded_fallbacks.fetch_add(1, Ordering::Relaxed);
                        let next = Slot::Leader {
                            guard,
                            vidx,
                            miss_idx: miss_ids[vidx].len(),
                            write_back_key: None,
                        };
                        miss_ids[vidx].push(enc.ids.as_ref().clone());
                        next
                    }
                };
            }
        }

        // Phase 2: each variant's misses hit that variant's queue in one
        // shot — a batch spanning variants fans out to every worker pool
        // at once. (Latency EWMAs are fed worker-side per request, so
        // the sequential leader collection below cannot skew them.)
        let rxs_by_variant: Vec<Vec<std::sync::mpsc::Receiver<PredVec>>> = miss_ids
            .into_iter()
            .enumerate()
            .map(|(vidx, ids)| {
                if ids.is_empty() {
                    Vec::new()
                } else {
                    tr.variants[vidx].queue.submit_many(ids)
                }
            })
            .collect();

        // Phase 3: resolve leaders first — completing them unparks any
        // followers of the same key later in this very batch. Computed
        // values for remote-owned keys are written back to their owner
        // asynchronously (fire-and-forget into the peer pool).
        for slot in slots.iter_mut() {
            if matches!(slot, Slot::Leader { .. }) {
                let placeholder = Slot::Done(Err(anyhow!("slot already taken")));
                let Slot::Leader { guard, vidx, miss_idx, write_back_key } =
                    std::mem::replace(slot, placeholder)
                else {
                    unreachable!()
                };
                let variant = &tr.variants[vidx];
                let res = rxs_by_variant[vidx][miss_idx]
                    .recv()
                    .map(|norm| variant.bundle.denormalize(norm))
                    .map_err(|_| anyhow!("prediction worker gone"));
                *slot = match res {
                    Ok(v) => {
                        guard.complete(v);
                        if let Some(key) = write_back_key {
                            if let Some(peer) =
                                self.cluster.as_ref().and_then(|c| c.owner_peer(key))
                            {
                                if peer.put(key, v) {
                                    self.stats.forwarded_puts.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        Slot::Done(Ok(routed(v, vidx)))
                    }
                    // `guard` drops here → followers are failed too.
                    Err(e) => Slot::Done(Err(e)),
                };
            }
        }

        // Phase 4: followers (their leaders have published by now, or will
        // from whichever other thread owns the flight).
        let out = slots
            .into_iter()
            .map(|slot| match slot {
                Slot::Done(r) => r,
                Slot::Follower { rx, vidx } => wait_for_leader(rx).map(|value| routed(value, vidx)),
                Slot::Probe { .. } => unreachable!("probes resolved in phase 1.5"),
                Slot::Leader { .. } => unreachable!("leaders resolved in phase 3"),
            })
            .collect();
        self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
        out
    }

    /// Full metrics for the wire protocol: service counters merged with
    /// the sharded cache's single-flight/contention view and the
    /// router's per-variant view (`routed_by_variant` + `variants`,
    /// keyed `target/variant`), plus the per-peer cluster view when a
    /// cluster is attached.
    pub fn stats_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let (chits, cmisses) = self.cache.stats();
        let mut routed = Json::obj();
        let mut variants = Json::obj();
        for (target, tr) in self.router.iter() {
            for v in &tr.variants {
                let key = format!("{}/{}", target.name(), v.name);
                let n = v.routed.load(Ordering::Relaxed);
                routed = routed.with(&key, Json::num(n as f64));
                let mut vj = Json::obj()
                    .with("model", Json::str(&v.bundle.model))
                    .with(
                        "targets",
                        Json::Arr(
                            v.bundle.targets.iter().map(|t| Json::str(t.name())).collect(),
                        ),
                    );
                if let Some(hw) = &v.bundle.hardware {
                    vj = vj.with("hardware", Json::str(hw));
                }
                // The live policy is read ONCE per variant so the pair
                // of knobs can never mix two retune generations.
                let live = v.queue.policy();
                variants = variants.with(
                    &key,
                    vj.with("max_len", Json::num(v.bundle.max_len as f64))
                        .with("routed", Json::num(n as f64))
                        .with(
                            "budget_downgrades",
                            Json::num(v.budget_downgrades.load(Ordering::Relaxed) as f64),
                        )
                        .with("ewma_us", Json::num(v.ewma_us.get()))
                        .with("p95_us", Json::num(v.p95_us.quantile()))
                        .with("policy_max_batch", Json::num(live.max_batch as f64))
                        .with(
                            "policy_max_wait_us",
                            Json::num(live.max_wait.as_micros() as f64),
                        )
                        .with("policy_retunes", Json::num(v.policy.retunes() as f64))
                        .with("queued", Json::num(v.queue.queued() as f64))
                        .with("span_entries", Json::num(v.span_table.len() as f64)),
                );
            }
        }
        let g = self.gauge_snapshot();
        let mut j = self
            .stats
            .to_json()
            .with("cache_entries", Json::num(g.cache_entries as f64))
            .with("cache_lookup_hits", Json::num(chits as f64))
            .with("cache_lookup_misses", Json::num(cmisses as f64))
            .with("coalesced_queries", Json::num(self.cache.coalesced() as f64))
            .with("cache_shard_contention", Json::num(self.cache.contended() as f64))
            .with("cache_shards", Json::num(self.cache.shard_count() as f64))
            .with("frontend_memo_entries", Json::num(g.frontend_memo_entries as f64))
            .with("frontend_memo_evictions", Json::num(self.memo.evictions() as f64))
            .with("len_memo_entries", Json::num(g.len_memo_entries as f64))
            .with("sessions_open", Json::num(g.sessions_open as f64))
            .with("offload_queue_depth", Json::num(g.offload_queue_depth as f64))
            .with("routed_by_variant", routed)
            .with("variants", variants);
        if let Some(cluster) = &self.cluster {
            j = j.with("cluster", cluster.stats_json());
        }
        j
    }

    /// The fastest credible latency estimate for `target` across its
    /// registered variants (p95 once seeded, else EWMA; cold variants
    /// excluded — see `TargetRoutes::min_latency_estimate_us`). `None`
    /// when the target is unserved or every variant is cold. This is
    /// the admission tier's optimistic bound for deadline shedding.
    pub fn min_latency_estimate_us(&self, target: Target) -> Option<f64> {
        self.router.routes(target).ok().and_then(|tr| tr.min_latency_estimate_us())
    }

    /// The full [`Service::stats_json`] view flattened into
    /// scrape-friendly text: one `name value` pair per line, nested
    /// objects dot-joined (`variants.regpressure/fc_ops.ewma_us 812`),
    /// in deterministic (BTreeMap) order. Numbers print plainly
    /// (`12`, not `12.0`), booleans as `0`/`1`, an empty object as
    /// `name 0` so documented names never vanish from the scrape;
    /// strings and arrays (non-metric detail like variant model names)
    /// are skipped. Served by the `metrics` wire command and the
    /// `mlir-cost metrics` CLI.
    pub fn metrics_text(&self) -> String {
        let mut out = String::new();
        flatten_metrics("", &self.stats_json(), &mut out);
        out
    }

    /// One consistent read of every point-in-time gauge the stats view
    /// reports. Counters (monotonic) may lag each other harmlessly, but
    /// gauges sampled at different instants inside one `stats_json` call
    /// used to produce impossible responses (e.g. an offload depth from
    /// after a drain next to a memo count from before it). All gauge
    /// reads happen here, back to back, and `stats_json` overlays them
    /// onto the counter export — the single place to extend when a new
    /// gauge is added, and the single read the line-protocol pin test
    /// asserts presence-zero against.
    fn gauge_snapshot(&self) -> GaugeSnapshot {
        GaugeSnapshot {
            cache_entries: self.cache.len(),
            frontend_memo_entries: self.memo.len(),
            len_memo_entries: self.router.len_memo.len(),
            sessions_open: self.stats.sessions_open.load(Ordering::Relaxed),
            offload_queue_depth: self.stats.offload_queue_depth.load(Ordering::Relaxed),
        }
    }

    /// Shut down every variant's worker pool (drains in-flight batches)
    /// and, when clustered, the peer pools.
    pub fn shutdown(&mut self) {
        for (_, tr) in self.router.iter_mut() {
            for variant in tr.variants.iter_mut() {
                variant.queue.close();
                for w in variant.workers.drain(..) {
                    let _ = w.join();
                }
            }
        }
        if let Some(cluster) = &self.cluster {
            cluster.shutdown();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Depth-first flatten of a stats JSON tree into `name value` lines
/// (see [`Service::metrics_text`] for the format contract). Nested
/// object keys are dot-joined onto `prefix`; numeric leaves print via
/// `f64`'s plain `Display`, booleans as `0`/`1`, nulls and empty
/// objects as `0`; strings and arrays carry no metric value and are
/// dropped.
fn flatten_metrics(prefix: &str, j: &crate::json::Json, out: &mut String) {
    use crate::json::Json;
    use std::fmt::Write as _;
    match j {
        Json::Obj(m) => {
            if m.is_empty() {
                if !prefix.is_empty() {
                    let _ = writeln!(out, "{prefix} 0");
                }
                return;
            }
            for (k, v) in m {
                if prefix.is_empty() {
                    flatten_metrics(k, v, out);
                } else {
                    flatten_metrics(&format!("{prefix}.{k}"), v, out);
                }
            }
        }
        Json::Num(n) => {
            let _ = writeln!(out, "{prefix} {n}");
        }
        Json::Bool(b) => {
            let _ = writeln!(out, "{prefix} {}", u8::from(*b));
        }
        Json::Null => {
            let _ = writeln!(out, "{prefix} 0");
        }
        Json::Str(_) | Json::Arr(_) => {}
    }
}

/// The deadline-shedding predicate: is `budget_us` already unmeetable
/// given the fastest credible per-invocation estimate and the current
/// offload queue depth? The projection is deliberately optimistic —
/// the request itself plus every queued job ahead of it, each at the
/// *fastest* variant's estimate — so a `true` here means even the
/// best case blows the budget and queueing the work is pointless.
/// Non-positive or non-finite inputs never shed: a cold router must
/// not reject traffic it knows nothing about.
pub fn deadline_unmeetable(min_estimate_us: f64, queue_depth: u64, budget_us: f64) -> bool {
    if !min_estimate_us.is_finite() || min_estimate_us <= 0.0 || !budget_us.is_finite() {
        return false;
    }
    min_estimate_us * (1.0 + queue_depth as f64) > budget_us
}

/// Park on a single-flight leader's answer.
fn wait_for_leader(rx: std::sync::mpsc::Receiver<Option<PredVec>>) -> Result<PredVec> {
    match rx.recv() {
        Ok(Some(v)) => Ok(v),
        Ok(None) => Err(anyhow!("coalesced prediction failed (leader errored)")),
        Err(_) => Err(anyhow!("coalesced prediction failed (leader vanished)")),
    }
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    ladder: Vec<(PathBuf, usize)>,
    params: Vec<Tensor>,
    max_len: usize,
    n_targets: usize,
    queue: Arc<BatchQueue>,
    stats: Arc<stats::ServiceStats>,
    ewma_us: Arc<stats::LatencyEwma>,
    p95_us: Arc<stats::QuantileSketch>,
    policy: Arc<PolicyController>,
    live: Arc<AtomicUsize>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // A worker that can't start must not strand submitters — but in a
        // pool, only the last live member may close the queue: while a
        // sibling serves, the variant stays up. The closer also drains
        // anything already queued so its receivers see the disconnect.
        let fail_startup = |msg: String| {
            eprintln!("{msg}");
            if live.fetch_sub(1, Ordering::SeqCst) == 1 {
                queue.close();
                while let Some(batch) = queue.next_batch() {
                    drop(batch);
                }
            }
        };
        // Per-thread PJRT client + compile (see Service::start_with docs).
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                fail_startup(format!("[coordinator] worker failed to create PJRT client: {e:#}"));
                return;
            }
        };
        // Compile the whole batch-size ladder, smallest rung first.
        let mut exes: Vec<(Executable, usize)> = Vec::with_capacity(ladder.len());
        for (path, batch) in &ladder {
            match rt.load(path) {
                Ok(exe) => exes.push((exe, *batch)),
                Err(e) => {
                    fail_startup(format!("[coordinator] worker failed to compile {path:?}: {e:#}"));
                    return;
                }
            }
        }
        eprintln!(
            "[coordinator] worker ready: {} ladder rung(s) b={:?} ({:.1} ms total compile)",
            exes.len(),
            exes.iter().map(|&(_, b)| b).collect::<Vec<_>>(),
            exes.iter().map(|(e, _)| e.compile_ms).sum::<f64>(),
        );
        while let Some(pending) = queue.next_batch() {
            if pending.is_empty() {
                continue;
            }
            let t_exec = Instant::now();
            serve_flush(&exes, &params, max_len, n_targets, &pending, &stats, &ewma_us, &p95_us);
            // One controller observation per drained flush (not per
            // ladder chunk): the flush is the unit max_batch/max_wait
            // bound. Execute-only time — queue wait must not feed back
            // into the wait target it is itself controlled by.
            policy.observe_flush(pending.len(), t_exec.elapsed().as_micros() as u64);
        }
    })
}

/// Chunk a drained flush of `n` queries over the compiled rung sizes
/// (ascending): full largest-rung chunks while the remainder still fills
/// one, then the smallest rung covering what's left — so a 3-query flush
/// pays 8 slots on a `[1, 8, 32]` ladder instead of 32. Returns
/// `(chunk_len, rung_batch)` pairs.
fn plan_chunks(n: usize, sizes: &[usize]) -> Vec<(usize, usize)> {
    let largest = sizes.last().copied().unwrap_or(1);
    let mut plan = Vec::new();
    let mut rem = n;
    while rem > 0 {
        if rem >= largest {
            plan.push((largest, largest));
            rem -= largest;
        } else {
            let b = sizes.iter().copied().find(|&b| b >= rem).unwrap_or(largest);
            plan.push((rem, b));
            rem = 0;
        }
    }
    plan
}

/// Run one drained flush through the executable ladder. Chunk failures
/// are isolated: a failed PJRT call drops that chunk's senders (its
/// receivers see a disconnect) and the remaining chunks still execute.
/// Each completed request's `submitted.elapsed()` (queue wait +
/// execute) is observed into the variant's latency EWMA and P² p95
/// sketch *before* its response is sent, so a caller that reads the
/// value and then the stats always sees the sample included.
#[allow(clippy::too_many_arguments)]
fn serve_flush(
    exes: &[(Executable, usize)],
    params: &[Tensor],
    max_len: usize,
    n_targets: usize,
    pending: &[Pending],
    stats: &stats::ServiceStats,
    ewma_us: &stats::LatencyEwma,
    p95_us: &stats::QuantileSketch,
) {
    let sizes: Vec<usize> = exes.iter().map(|&(_, b)| b).collect();
    let mut off = 0;
    for (take, batch) in plan_chunks(pending.len(), &sizes) {
        let chunk = &pending[off..off + take];
        off += take;
        let exe = exes
            .iter()
            .find(|&&(_, b)| b == batch)
            .map(|(e, _)| e)
            .expect("plan_chunks only picks compiled rungs");
        match run_chunk(exe, params, max_len, batch, n_targets, chunk) {
            Ok(values) => {
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.batched_queries.fetch_add(take as u64, Ordering::Relaxed);
                stats.batch_slots.fetch_add(batch as u64, Ordering::Relaxed);
                stats.padded_slots.fetch_add((batch - take) as u64, Ordering::Relaxed);
                stats.record_exec(batch);
                for (p, v) in chunk.iter().zip(values) {
                    let us = p.submitted.elapsed().as_micros() as f64;
                    ewma_us.observe(us);
                    p95_us.observe(us);
                    let _ = p.respond.send(v);
                }
            }
            Err(e) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("[coordinator] chunk of {take} on b={batch} failed: {e:#}");
                // Chunk senders drop → their receivers see disconnect.
            }
        }
    }
}

/// Pack one chunk of requests into a dense row-major `[batch, max_len]`
/// i32 buffer. Every row is padded (or truncated) to `max_len`
/// *individually* — a short id row must never shift the rows after it, or
/// the whole batch silently predicts on misaligned tokens. Unused trailing
/// slots stay zeroed (0 = PAD).
fn pack_batch(chunk: &[Pending], max_len: usize, batch: usize) -> Vec<i32> {
    let mut ids = vec![0i32; batch * max_len];
    for (row, p) in chunk.iter().enumerate() {
        for (col, &x) in p.ids.iter().take(max_len).enumerate() {
            ids[row * max_len + col] = x as i32;
        }
    }
    ids
}

/// Execute one chunk (already sized to fit `batch`) on one rung. ONE
/// forward pass yields every declared characteristic per row: a
/// `[B, K]` multi-output head gives each row its K normalized values,
/// while a legacy `[B]` head broadcasts its single output across the
/// bundle's declared width (mirroring `Trainer::predict_set` — each
/// slot still denormalizes by its own per-target stats downstream).
fn run_chunk(
    exe: &Executable,
    params: &[Tensor],
    max_len: usize,
    batch: usize,
    n_targets: usize,
    chunk: &[Pending],
) -> Result<Vec<PredVec>> {
    debug_assert!(chunk.len() <= batch);
    let ids = pack_batch(chunk, max_len, batch);
    let mut inputs = params.to_vec();
    inputs.push(Tensor::i32(vec![batch as i64, max_len as i64], ids)?);
    let res = exe.run(&inputs)?;
    let vals = res[0].as_f32()?;
    let k = n_targets.max(1);
    let wide = vals.len() >= batch * k; // [B, K] row-major head
    let mut out = Vec::with_capacity(chunk.len());
    for row in 0..chunk.len() {
        let mut p = PredVec::new();
        for j in 0..k {
            let v = if wide { vals[row * k + j] } else { vals[row] };
            p.push(v as f64);
        }
        out.push(p);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TargetStats;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::print_function;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::{Path, PathBuf};
    use std::sync::mpsc::channel;
    use std::sync::Barrier;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    fn test_service() -> Option<Service> {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let streams = vec![vec!["xpu.matmul".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
        let bundle = Bundle::untrained(
            &manifest,
            "fc_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab,
            stats,
        )
        .unwrap();
        Some(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        )
    }

    fn graph_text(structure_seed: u64, shape_seed: u64) -> String {
        let spec = GraphSpec { family: Family::Mlp, structure_seed, shape_seed };
        print_function(&generate(&spec).unwrap())
    }

    #[test]
    fn end_to_end_predict() {
        let Some(svc) = test_service() else { return };
        let text = graph_text(1, 2);
        let v = svc.predict(Target::RegPressure, &text).unwrap();
        assert!(v.is_finite());
        // Same query → cache hit, identical answer.
        let v2 = svc.predict(Target::RegPressure, &text).unwrap();
        assert_eq!(v, v2);
        let (hits, _) = svc.cache.stats();
        assert_eq!(hits, 1);
    }

    #[test]
    fn frontend_memo_skips_reencode_on_duplicates() {
        let Some(svc) = test_service() else { return };
        let text = graph_text(31, 32);
        let v1 = svc.predict(Target::RegPressure, &text).unwrap();
        assert_eq!(svc.stats.frontend_memo_hits.load(Ordering::Relaxed), 0);
        // Same text again: front end must come from the memo.
        let v2 = svc.predict(Target::RegPressure, &text).unwrap();
        assert_eq!(v1, v2);
        assert_eq!(svc.stats.frontend_memo_hits.load(Ordering::Relaxed), 1);
        // And the counters surface in the merged stats view.
        let j = svc.stats_json();
        assert_eq!(j.req_f64("frontend_memo_hits").unwrap(), 1.0);
        assert!(j.req_f64("encode_ns").unwrap() > 0.0);
        assert!(j.req_f64("frontend_memo_entries").unwrap() >= 1.0);
    }

    #[test]
    fn unknown_target_is_error() {
        let Some(svc) = test_service() else { return };
        let text = graph_text(1, 2);
        assert!(svc.predict(Target::Cycles, &text).is_err());
    }

    /// The tentpole end to end: a bundle declaring several
    /// characteristics answers ALL of them from ONE forward pass — one
    /// batched model invocation, a full-width vector back, every slot
    /// denormalized by its own target's stats.
    #[test]
    fn multi_target_bundle_predicts_all_characteristics_in_one_pass() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let streams = vec![vec!["xpu.matmul".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let bundle = Bundle::untrained_multi(
            &manifest,
            "fc_ops",
            &[Target::Cycles, Target::XpuUtil],
            Scheme::OpsOnly,
            vocab,
            vec![
                TargetStats { mean: 900.0, std: 200.0, min: 100.0, max: 4000.0 },
                TargetStats { mean: 40.0, std: 10.0, min: 0.0, max: 100.0 },
            ],
            Some("xpu-v1".to_string()),
        )
        .unwrap();
        let svc =
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap();
        let text = graph_text(3, 4);
        let r = svc
            .predict_full(Target::Cycles, &text, None, &[Target::Cycles, Target::XpuUtil])
            .unwrap();
        assert_eq!(r.targets, vec![Target::Cycles, Target::XpuUtil]);
        assert_eq!(r.value.len(), 2);
        assert!(r.value.iter().all(|v| v.is_finite()));
        assert_eq!(r.value_for(Target::Cycles), Some(r.value.first()));
        assert!(r.value_for(Target::RegPressure).is_none());
        // ONE model invocation produced the whole vector.
        assert_eq!(svc.stats.batched_queries.load(Ordering::Relaxed), 1);
        // The scalar surface still serves the primary target.
        assert_eq!(svc.predict(Target::Cycles, &text).unwrap(), r.value.first());
        // The per-variant stats view names the declared targets.
        let j = svc.stats_json();
        let v = j.get("variants").unwrap().get("cycles/fc_ops").unwrap();
        let names: Vec<&str> =
            v.req_arr("targets").unwrap().iter().filter_map(|t| t.as_str()).collect();
        assert_eq!(names, vec!["cycles", "xpuutil"]);
    }

    /// A request requiring characteristics no variant serves fails with
    /// a clean `targets_not_served` error naming the gap — never a
    /// silent partial answer — and the counter moves.
    #[test]
    fn unserved_characteristics_are_a_clean_error() {
        let Some(svc) = test_service() else { return };
        let text = graph_text(1, 2);
        let err = svc
            .predict_full(
                Target::RegPressure,
                &text,
                None,
                &[Target::RegPressure, Target::Cycles],
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("targets_not_served"), "unexpected error: {msg}");
        assert!(msg.contains("cycles"), "missing characteristic not named: {msg}");
        assert_eq!(svc.stats.targets_not_served.load(Ordering::Relaxed), 1);
        // Length-uncovered queries keep their own error and counter.
        assert_eq!(svc.stats.no_covering_variant.load(Ordering::Relaxed), 0);
        // The service keeps serving satisfiable queries afterwards.
        assert!(svc
            .predict_full(Target::RegPressure, &text, None, &[Target::RegPressure])
            .is_ok());
        assert_eq!(svc.stats.targets_not_served.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_queries_batch_together() {
        let Some(svc) = test_service() else { return };
        let svc = Arc::new(svc);
        // 24 texts across every family, skipping seeds whose graph
        // exceeds fc_ops's max_len (128 ops-only tokens) — the router
        // rejects over-long queries instead of truncating them.
        let texts: Vec<String> = {
            let mut texts = Vec::new();
            let mut i = 0u64;
            while texts.len() < 24 {
                let spec = GraphSpec {
                    family: Family::ALL[(i % 7) as usize],
                    structure_seed: i,
                    shape_seed: 1000 + i,
                };
                i += 1;
                let f = generate(&spec).unwrap();
                if token_count(&f, Scheme::OpsOnly) <= 128 {
                    texts.push(print_function(&f));
                }
            }
            texts
        };
        let mut handles = Vec::new();
        for t in texts {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                svc.predict(Target::RegPressure, &t).unwrap()
            }));
        }
        for h in handles {
            assert!(h.join().unwrap().is_finite());
        }
        assert!(svc.stats.mean_batch_size() > 1.0, "no batching happened");
        // The batching-health counters move with the batches.
        assert!(svc.stats.batch_slots.load(Ordering::Relaxed) >= 24);
        assert!(svc.stats.batch_fill_ratio() > 0.0);
    }

    #[test]
    fn single_flight_coalesces_32_identical_queries() {
        let Some(svc) = test_service() else { return };
        let svc = Arc::new(svc);
        let text = Arc::new(graph_text(77, 78));
        let barrier = Arc::new(Barrier::new(32));
        let mut handles = Vec::new();
        for _ in 0..32 {
            let svc = svc.clone();
            let text = text.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                svc.predict(Target::RegPressure, &text).unwrap()
            }));
        }
        let values: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(values.windows(2).all(|w| w[0] == w[1]), "divergent answers");
        // The heart of single-flight: 32 identical concurrent queries pay
        // for exactly ONE model invocation.
        assert_eq!(
            svc.stats.batched_queries.load(Ordering::Relaxed),
            1,
            "duplicate queries reached the model"
        );
        let (hits, _) = svc.cache.stats();
        assert_eq!(svc.cache.coalesced() + hits + 1, 32);
    }

    #[test]
    fn predict_many_mixed_hit_miss_malformed() {
        let Some(svc) = test_service() else { return };
        let a = graph_text(11, 12);
        let b = graph_text(13, 14);
        // a appears twice: the second occurrence coalesces onto the first
        // within the same batch call.
        let texts = [a.as_str(), "not mlir at all", a.as_str(), b.as_str()];
        let out = svc.predict_many(Target::RegPressure, &texts);
        assert_eq!(out.len(), 4);
        let va = out[0].as_ref().expect("valid input failed");
        assert!(va.is_finite());
        assert!(out[1].is_err(), "malformed input must fail alone");
        assert_eq!(out[2].as_ref().unwrap(), va, "duplicate diverged");
        assert!(out[3].as_ref().unwrap().is_finite());
        // Second call: everything valid is now a cache hit.
        let out2 = svc.predict_many(Target::RegPressure, &[a.as_str(), b.as_str()]);
        assert!(out2.iter().all(|r| r.is_ok()));
        let (hits, _) = svc.cache.stats();
        assert!(hits >= 2, "warm batch should hit the cache: {hits}");
        assert_eq!(svc.stats.batch_requests.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn predict_many_unknown_target_fails_all() {
        let Some(svc) = test_service() else { return };
        let a = graph_text(1, 2);
        let out = svc.predict_many(Target::Cycles, &[a.as_str(), a.as_str()]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.is_err()));
    }

    #[test]
    fn malformed_mlir_is_rejected() {
        let Some(svc) = test_service() else { return };
        assert!(svc.predict(Target::RegPressure, "not mlir at all").is_err());
    }

    /// A single query through a ladder-equipped head must execute on the
    /// smallest compiled rung, not the `max_batch` one — observable as
    /// `exec_by_batch` recording the small rung and `padded_slots`
    /// strictly below the single-executable path's `max_batch - 1`.
    #[test]
    fn small_flush_picks_smallest_covering_rung() {
        let Some(svc) = test_service() else { return };
        let adir = artifacts_dir();
        let manifest = Manifest::load(&adir).unwrap();
        let ladder = manifest.model("fc_ops").unwrap().predict_ladder(32, false);
        let smallest = ladder[0].1;
        let single_exe_batch = ladder.last().unwrap().1;

        let text = graph_text(91, 92);
        svc.predict(Target::RegPressure, &text).unwrap();

        let by_batch = svc.stats.exec_by_batch();
        assert_eq!(by_batch.get(&smallest), Some(&1), "exec_by_batch: {by_batch:?}");
        let padded = svc.stats.padded_slots.load(Ordering::Relaxed);
        assert_eq!(padded, (smallest - 1) as u64);
        if ladder.len() > 1 {
            assert!(
                padded < (single_exe_batch - 1) as u64,
                "ladder did not beat the single-executable padding"
            );
        }
    }

    /// Two workers per head drain one shared queue; every query resolves
    /// and the flushes were executed (not stranded on either worker).
    #[test]
    fn worker_pool_drains_shared_queue() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            return;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let streams = vec![vec!["xpu.matmul".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
        let bundle = Bundle::untrained(
            &manifest,
            "fc_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab,
            stats,
        )
        .unwrap();
        let svc = Arc::new(
            Service::start_with(
                manifest,
                vec![bundle],
                BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_micros(500) },
                ServeOptions { workers_per_head: 2, ..ServeOptions::default() },
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..24u64 {
            let svc = svc.clone();
            let text = graph_text(200 + i, 300 + i);
            handles.push(std::thread::spawn(move || {
                svc.predict(Target::RegPressure, &text).unwrap()
            }));
        }
        for h in handles {
            assert!(h.join().unwrap().is_finite());
        }
        assert!(svc.stats.batches.load(Ordering::Relaxed) >= 1);
        // Some of the 24 texts may encode identically (tiny test vocab)
        // and dedupe via the cache/single-flight before reaching the
        // queue — assert the pool drained everything that DID enter,
        // not an exact count.
        let bq = svc.stats.batched_queries.load(Ordering::Relaxed);
        assert!((1..=24).contains(&bq), "queue under/over-drained: {bq}");
    }

    // ---- routing tier: 3-variant services (artifact-gated) ----

    fn reg_bundle(manifest: &Manifest, model: &str, scheme: Scheme) -> Bundle {
        let vocab = Vocab::build(vec![vec!["xpu.relu".to_string()]].iter(), 1);
        let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
        Bundle::untrained(manifest, model, Target::RegPressure, scheme, vocab, stats).unwrap()
    }

    /// RegPressure served by three variants: fc_ops + lstm_ops
    /// (max_len 128) and conv_full (max_len 512), all ops-only.
    fn three_variant_service() -> Option<Service> {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let specs = vec![
            VariantSpec {
                name: "fc_ops".into(),
                bundle: reg_bundle(&manifest, "fc_ops", Scheme::OpsOnly),
            },
            VariantSpec {
                name: "lstm_ops".into(),
                bundle: reg_bundle(&manifest, "lstm_ops", Scheme::OpsOnly),
            },
            VariantSpec {
                name: "conv_full".into(),
                bundle: reg_bundle(&manifest, "conv_full", Scheme::OpsOnly),
            },
        ];
        Some(
            Service::start_variants(
                manifest,
                specs,
                BatchPolicy::default(),
                ServeOptions::default(),
            )
            .unwrap(),
        )
    }

    /// A linear chain of `n_ops` relu ops: `n_ops + 5` ops-only tokens
    /// (func, arg shape, ->, ret shape, return), so tests can dial a
    /// query's token length precisely.
    fn chain_text(n_ops: usize) -> String {
        use crate::mlir::{Attrs, DType, FuncBuilder, Type, XpuOp};
        let mut b = FuncBuilder::new("chain");
        let mut v = b.arg(Type::tensor(vec![4, 8], DType::F32));
        for _ in 0..n_ops {
            v = b.xpu(XpuOp::Relu, &[v], Attrs::new()).unwrap();
        }
        print_function(&b.ret(&[v]).unwrap())
    }

    #[test]
    fn router_picks_cheapest_covering_variant_by_token_length() {
        let Some(svc) = three_variant_service() else { return };
        // 15 tokens: fits every variant → the smallest (fc_ops, which
        // sorts before lstm_ops at equal max_len) serves.
        let short = chain_text(10);
        let r = svc.predict_with(Target::RegPressure, &short, None).unwrap();
        assert_eq!(&*r.variant, "fc_ops");
        assert!(r.value.is_finite());
        // 155 tokens: only conv_full (512) covers.
        let long = chain_text(150);
        let r = svc.predict_with(Target::RegPressure, &long, None).unwrap();
        assert_eq!(&*r.variant, "conv_full");
        // The per-variant stats view reflects both decisions.
        let j = svc.stats_json();
        let routed = j.get("routed_by_variant").unwrap();
        assert_eq!(routed.req_f64("regpressure/fc_ops").unwrap(), 1.0);
        assert_eq!(routed.req_f64("regpressure/lstm_ops").unwrap(), 0.0);
        assert_eq!(routed.req_f64("regpressure/conv_full").unwrap(), 1.0);
        let variants = j.get("variants").unwrap();
        let conv = variants.get("regpressure/conv_full").unwrap();
        assert_eq!(conv.req_f64("max_len").unwrap(), 512.0);
        assert_eq!(conv.req_f64("routed").unwrap(), 1.0);
        assert!(conv.req_f64("ewma_us").unwrap() > 0.0, "miss must feed the EWMA");
    }

    #[test]
    fn uncovered_token_length_is_a_clean_error() {
        let Some(svc) = three_variant_service() else { return };
        // 605 tokens: longer than every variant's max_len.
        let huge = chain_text(600);
        let err = svc.predict(Target::RegPressure, &huge).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("covers token length"), "unexpected error: {msg}");
        assert_eq!(svc.stats.no_covering_variant.load(Ordering::Relaxed), 1);
        // The service keeps serving covered queries afterwards.
        assert!(svc.predict(Target::RegPressure, &chain_text(5)).is_ok());
        assert_eq!(svc.stats.no_covering_variant.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn budget_downgrades_to_faster_variant_and_is_counted() {
        let Some(svc) = three_variant_service() else { return };
        let seed = |svc: &Service| {
            svc.set_variant_ewma_us(Target::RegPressure, "fc_ops", 300.0).unwrap();
            svc.set_variant_ewma_us(Target::RegPressure, "lstm_ops", 900.0).unwrap();
            svc.set_variant_ewma_us(Target::RegPressure, "conv_full", 5_000.0).unwrap();
        };
        seed(&svc);
        // 155 tokens prefers conv_full (5000us) but the 1000us budget
        // downgrades to the LARGEST fitting smaller variant: lstm_ops.
        let r = svc
            .predict_with(Target::RegPressure, &chain_text(150), Some(1_000))
            .unwrap();
        assert_eq!(&*r.variant, "lstm_ops");
        assert_eq!(svc.stats.budget_downgrades.load(Ordering::Relaxed), 1);
        // Re-seed (the downgraded invocation fed lstm_ops's EWMA) and
        // send an unsatisfiable budget: nothing fits 10us, so the
        // smallest COVERING variant serves and no downgrade is counted.
        seed(&svc);
        let r = svc
            .predict_with(Target::RegPressure, &chain_text(151), Some(10))
            .unwrap();
        assert_eq!(&*r.variant, "conv_full");
        assert_eq!(svc.stats.budget_downgrades.load(Ordering::Relaxed), 1);
        // A short query under a generous budget is never downgraded.
        let r = svc
            .predict_with(Target::RegPressure, &chain_text(6), Some(1_000_000))
            .unwrap();
        assert_eq!(&*r.variant, "fc_ops");
        assert_eq!(svc.stats.budget_downgrades.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batch_spanning_variants_keeps_input_order() {
        let Some(svc) = three_variant_service() else { return };
        let short_a = chain_text(5);
        let long = chain_text(200);
        let short_b = chain_text(7);
        // short / long / short / duplicate-long: rows must come back in
        // input order with per-row variants, the duplicate coalescing
        // onto the first long entry.
        let texts =
            [short_a.as_str(), long.as_str(), short_b.as_str(), long.as_str()];
        let out = svc.predict_many_with(Target::RegPressure, &texts, None);
        assert_eq!(out.len(), 4);
        let rows: Vec<&RoutedPrediction> =
            out.iter().map(|r| r.as_ref().expect("batch entry failed")).collect();
        assert_eq!(&*rows[0].variant, "fc_ops");
        assert_eq!(&*rows[1].variant, "conv_full");
        assert_eq!(&*rows[2].variant, "fc_ops");
        assert_eq!(&*rows[3].variant, "conv_full");
        assert_eq!(rows[1].value, rows[3].value, "duplicate long query diverged");
        // Each row matches what a single predict of the same text now
        // serves from the cache — i.e. rows were not permuted.
        for (text, row) in texts.iter().zip(&rows) {
            assert_eq!(
                svc.predict(Target::RegPressure, text).unwrap(),
                row.value.first(),
                "row out of order"
            );
        }
        // Both variants executed work for ONE batch request.
        assert_eq!(svc.stats.batch_requests.load(Ordering::Relaxed), 1);
        let j = svc.stats_json();
        let routed = j.get("routed_by_variant").unwrap();
        assert!(routed.req_f64("regpressure/fc_ops").unwrap() >= 2.0);
        assert!(routed.req_f64("regpressure/conv_full").unwrap() >= 2.0);
    }

    #[test]
    fn invalid_variant_sets_fail_before_spawning() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            return;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        // Duplicate name within a target.
        let dup = Service::start_variants(
            manifest.clone(),
            vec![
                VariantSpec {
                    name: "v".into(),
                    bundle: reg_bundle(&manifest, "fc_ops", Scheme::OpsOnly),
                },
                VariantSpec {
                    name: "v".into(),
                    bundle: reg_bundle(&manifest, "lstm_ops", Scheme::OpsOnly),
                },
            ],
            BatchPolicy::default(),
            ServeOptions::default(),
        );
        assert!(format!("{:#}", dup.unwrap_err()).contains("duplicate variant name"));
        // Mixed schemes within a target.
        let mixed = Service::start_variants(
            manifest.clone(),
            vec![
                VariantSpec {
                    name: "a".into(),
                    bundle: reg_bundle(&manifest, "fc_ops", Scheme::OpsOnly),
                },
                VariantSpec {
                    name: "b".into(),
                    bundle: reg_bundle(&manifest, "conv_full", Scheme::OpsOperands),
                },
            ],
            BatchPolicy::default(),
            ServeOptions::default(),
        );
        assert!(format!("{:#}", mixed.unwrap_err()).contains("mix tokenization schemes"));
    }

    // ---- plan_chunks: pure, artifact-free ladder-selection tests ----

    #[test]
    fn plan_chunks_picks_smallest_covering_rung() {
        let ladder = [1usize, 8, 32];
        assert_eq!(plan_chunks(1, &ladder), vec![(1, 1)]);
        assert_eq!(plan_chunks(3, &ladder), vec![(3, 8)]);
        assert_eq!(plan_chunks(8, &ladder), vec![(8, 8)]);
        assert_eq!(plan_chunks(9, &ladder), vec![(9, 32)]);
        assert_eq!(plan_chunks(32, &ladder), vec![(32, 32)]);
    }

    #[test]
    fn plan_chunks_splits_oversized_flushes() {
        let ladder = [1usize, 8, 32];
        // 40 = one full b=32 chunk + an 8-query remainder on b=8.
        assert_eq!(plan_chunks(40, &ladder), vec![(32, 32), (8, 8)]);
        // 33 = full chunk + a single query on the b=1 rung: 0 padding.
        assert_eq!(plan_chunks(33, &ladder), vec![(32, 32), (1, 1)]);
        // 70 = two full chunks + 6 on b=8.
        assert_eq!(plan_chunks(70, &ladder), vec![(32, 32), (32, 32), (6, 8)]);
        let padded: usize = plan_chunks(70, &ladder).iter().map(|&(n, b)| b - n).sum();
        assert_eq!(padded, 2);
    }

    #[test]
    fn plan_chunks_single_rung_matches_old_padding() {
        // A one-executable ladder degenerates to the pre-ladder behavior:
        // every chunk padded to the single compiled size.
        assert_eq!(plan_chunks(5, &[32]), vec![(5, 32)]);
        assert_eq!(plan_chunks(40, &[32]), vec![(32, 32), (8, 32)]);
        assert_eq!(plan_chunks(0, &[32]), Vec::<(usize, usize)>::new());
    }

    // ---- pack_batch: pure, artifact-free regression tests ----

    fn mk_pending(ids: Vec<u32>) -> Pending {
        // pack_batch never touches the response channel or timestamp.
        let (tx, _rx) = channel();
        Pending { ids, respond: tx, submitted: Instant::now() }
    }

    /// Regression for the misaligned-batch bug: the old packer
    /// concatenated rows and zero-padded once at the end, so one short row
    /// shifted every row after it and the batch silently predicted on the
    /// wrong tokens.
    #[test]
    fn pack_batch_pads_each_row_independently() {
        let chunk = vec![
            mk_pending(vec![5, 6]),             // short: padded in place
            mk_pending(vec![7, 8, 9, 10]),      // exact
            mk_pending(vec![]),                 // empty
            mk_pending(vec![1, 2, 3, 4, 5, 6]), // over-long: truncated
        ];
        let ids = pack_batch(&chunk, 4, 6);
        assert_eq!(ids.len(), 24);
        assert_eq!(&ids[0..4], &[5, 6, 0, 0], "short row not padded in place");
        // With the old concat-then-resize packer, this row began at offset
        // 2 instead of max_len — the regression under test.
        assert_eq!(&ids[4..8], &[7, 8, 9, 10], "row 1 misaligned");
        assert_eq!(&ids[8..12], &[0, 0, 0, 0], "empty row must be all PAD");
        assert_eq!(&ids[12..16], &[1, 2, 3, 4], "over-long row not truncated");
        assert_eq!(&ids[16..24], &[0i32; 8], "unused slots must stay PAD");
    }

    #[test]
    fn pack_batch_full_chunk_unchanged() {
        let chunk: Vec<Pending> =
            (0..3).map(|r| mk_pending(vec![r * 10, r * 10 + 1])).collect();
        let ids = pack_batch(&chunk, 2, 3);
        assert_eq!(ids, vec![0, 1, 10, 11, 20, 21]);
    }

    // ---- metrics flattening + deadline shedding: pure helpers ----

    #[test]
    fn flatten_metrics_dot_joins_and_skips_non_numeric() {
        use crate::json::Json;
        let j = Json::obj()
            .with("plain", Json::num(12.0))
            .with("frac", Json::num(0.5))
            .with("on", Json::Bool(true))
            .with("off", Json::Bool(false))
            .with("missing", Json::Null)
            .with("label", Json::str("skipped"))
            .with("list", Json::Arr(vec![Json::num(1.0)]))
            .with("empty", Json::obj())
            .with("nest", Json::obj().with("inner", Json::num(3.0)));
        let mut out = String::new();
        flatten_metrics("", &j, &mut out);
        // BTreeMap order: empty, frac, label, list, missing, nest, off, on, plain.
        assert_eq!(out, "empty 0\nfrac 0.5\nmissing 0\nnest.inner 3\noff 0\non 1\nplain 12\n");
    }

    #[test]
    fn flatten_metrics_empty_root_emits_nothing() {
        use crate::json::Json;
        let mut out = String::new();
        flatten_metrics("", &Json::obj(), &mut out);
        assert_eq!(out, "");
    }

    #[test]
    fn deadline_unmeetable_projects_queue_depth() {
        // 100us fastest estimate, empty queue: a 150us budget is fine,
        // a 99us budget is not.
        assert!(!deadline_unmeetable(100.0, 0, 150.0));
        assert!(deadline_unmeetable(100.0, 0, 99.0));
        // Three jobs queued ahead: best case 4 invocations = 400us.
        assert!(deadline_unmeetable(100.0, 3, 399.0));
        assert!(!deadline_unmeetable(100.0, 3, 400.0));
        // A cold router (no credible estimate) never sheds.
        assert!(!deadline_unmeetable(0.0, 100, 1.0));
        assert!(!deadline_unmeetable(-1.0, 100, 1.0));
        assert!(!deadline_unmeetable(f64::NAN, 100, 1.0));
        assert!(!deadline_unmeetable(100.0, 100, f64::INFINITY));
    }
}

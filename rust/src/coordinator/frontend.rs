//! Text-level encode memo: the front half of the serving path.
//!
//! Autotuning probes re-send the *same MLIR text* thousands of times
//! (every pass, every schedule candidate). Before this memo, each of
//! those duplicates paid a full lex→parse→tokenize→encode pass just to
//! discover it was a prediction-cache hit. The memo keys on
//! `FxHash(target, variant, model, mlir_text)` — target *and* variant
//! included because two registered variants (of one target or of two)
//! may share a model architecture while carrying different
//! vocab/max_len/stats, and their encodings must never cross-serve —
//! and stores the finished `(ids, cache_key)` pair, so a duplicate
//! query's entire front end collapses to ONE hash pass over the input
//! text ([`FrontendMemo::text_hash`], whose digest also derives the
//! router's token-length memo key) plus two short sharded map probes
//! (length, then encoding).
//!
//! Same trust model as the prediction cache: keys are 64-bit hashes with
//! no stored-text verification — a collision would serve the wrong row,
//! but at the memo's working-set size the probability is ~2⁻⁴⁰ per pair
//! and the inputs are compiler-internal, not adversarial.
//!
//! Eviction is wholesale per shard (clear-on-full) rather than LRU: the
//! memo is a cheap accelerator in front of the real LRU
//! [`super::cache::PredictionCache`], duplicate-heavy traffic re-warms a
//! cleared shard in one miss per distinct query, and clearing keeps the
//! insert path to a single hash probe.

use fxhash::{FxHashMap, FxHasher};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count (power of two), mirroring the prediction cache's layout.
pub const DEFAULT_MEMO_SHARDS: usize = 16;

/// A memoized front-end result: the padded id row and its
/// prediction-cache key. `ids` is shared (`Arc`) so a memo hit hands the
/// row out without copying `max_len` u32s; the rare prediction-cache miss
/// clones it once when entering the batch queue.
#[derive(Debug, Clone)]
pub struct CachedEncode {
    pub ids: Arc<Vec<u32>>,
    pub key: u64,
}

/// Generic sharded clear-on-full memo: `u64` hash key → any cloneable
/// value, `N` power-of-two shards each behind its own `Mutex`. Both
/// serving-path memos are instances of this one type — the per-variant
/// encode memo ([`FrontendMemo`] = `ShardedMemo<CachedEncode>`) and the
/// router's token-length memo (`LenMemo` in `super::router`) — so the
/// shard selection, capacity clamp, and the clear-on-full subtlety
/// (refreshing an existing key at capacity must not wipe the shard)
/// are written and tested once.
pub struct ShardedMemo<V> {
    shards: Vec<Mutex<FxHashMap<u64, V>>>,
    shard_bits: u32,
    per_shard_cap: usize,
    evictions: AtomicU64,
}

impl<V: Clone> ShardedMemo<V> {
    /// Memo holding ~`capacity` entries across [`DEFAULT_MEMO_SHARDS`]
    /// shards.
    pub fn new(capacity: usize) -> ShardedMemo<V> {
        ShardedMemo::with_shards(capacity, DEFAULT_MEMO_SHARDS)
    }

    /// Explicit shard count (rounded to a power of two, clamped so tiny
    /// capacities are not multiplied — same rule as the prediction cache).
    pub fn with_shards(capacity: usize, shards: usize) -> ShardedMemo<V> {
        let n = shards
            .max(1)
            .next_power_of_two()
            .min(capacity.max(1).next_power_of_two());
        ShardedMemo {
            shards: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
            shard_bits: n.trailing_zeros(),
            per_shard_cap: (capacity / n).max(1),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<FxHashMap<u64, V>> {
        &self.shards[super::cache::shard_index(key, self.shard_bits)]
    }

    pub fn get(&self, key: u64) -> Option<V> {
        self.shard(key).lock().unwrap().get(&key).cloned()
    }

    pub fn insert(&self, key: u64, value: V) {
        let mut shard = self.shard(key).lock().unwrap();
        if shard.len() >= self.per_shard_cap && !shard.contains_key(&key) {
            shard.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        shard.insert(key, value);
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        // Short-circuit on the first occupied shard instead of summing
        // every shard's length under its lock like `len()` does.
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Wholesale clear-on-full shard wipes since startup. Sustained
    /// growth means the working set exceeds capacity and the memo is
    /// churning instead of accelerating.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Sharded `hash(target, variant, model, text)` → [`CachedEncode`] memo.
/// Hit/miss accounting lives on `ServiceStats` (`frontend_memo_hits`),
/// not here — the probe itself stays free of atomic traffic.
pub type FrontendMemo = ShardedMemo<CachedEncode>;

impl FrontendMemo {
    /// One FxHash pass over the raw MLIR text — the only *full-text*
    /// hash a query ever pays. Every memo key (this memo's and the
    /// router's token-length memo's) is derived from this digest with
    /// short salts, so routing + encode memoization together cost one
    /// text traversal, not one per memo.
    pub fn text_hash(mlir_text: &str) -> u64 {
        let mut h = FxHasher::default();
        mlir_text.hash(&mut h);
        h.finish()
    }

    /// The memo key for a query over `(target, variant, model, text)`.
    /// `target` and the registered variant name are both part of the
    /// key because every serving variant owns its own vocab/scheme/
    /// max_len even when the model architecture name is shared across
    /// variants or targets.
    pub fn text_key(target: &str, variant: &str, model: &str, mlir_text: &str) -> u64 {
        FrontendMemo::key_from_hash(target, variant, model, FrontendMemo::text_hash(mlir_text))
    }

    /// [`FrontendMemo::text_key`] from a precomputed [`FrontendMemo::text_hash`]
    /// digest — hashes only the short salt strings.
    pub fn key_from_hash(target: &str, variant: &str, model: &str, text_hash: u64) -> u64 {
        let mut h = FxHasher::default();
        target.hash(&mut h);
        variant.hash(&mut h);
        model.hash(&mut h);
        text_hash.hash(&mut h);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(ids: Vec<u32>, key: u64) -> CachedEncode {
        CachedEncode { ids: Arc::new(ids), key }
    }

    #[test]
    fn same_text_same_key_then_hit() {
        let text = "func.func @f() {\n  return\n}\n";
        let k1 = FrontendMemo::text_key("regpressure", "small", "fc_ops", text);
        let k2 = FrontendMemo::text_key("regpressure", "small", "fc_ops", text);
        assert_eq!(k1, k2, "identical (target, variant, model, text) must share a memo key");
        let memo = FrontendMemo::new(64);
        assert!(memo.get(k1).is_none());
        memo.insert(k1, enc(vec![1, 2, 3], 99));
        let got = memo.get(k2).expect("second lookup must hit");
        assert_eq!(*got.ids, vec![1, 2, 3]);
        assert_eq!(got.key, 99);
    }

    #[test]
    fn keys_separate_targets_variants_models_and_texts() {
        let t = "func.func @f() {\n  return\n}\n";
        // Two variants may share a model architecture name while owning
        // different vocabs — target AND variant must split the entries.
        assert_ne!(
            FrontendMemo::text_key("regpressure", "v", "fc_ops", t),
            FrontendMemo::text_key("cycles", "v", "fc_ops", t)
        );
        assert_ne!(
            FrontendMemo::text_key("regpressure", "small", "fc_ops", t),
            FrontendMemo::text_key("regpressure", "wide", "fc_ops", t)
        );
        assert_ne!(
            FrontendMemo::text_key("regpressure", "v", "fc_ops", t),
            FrontendMemo::text_key("regpressure", "v", "conv_ops", t)
        );
        assert_ne!(
            FrontendMemo::text_key("regpressure", "v", "fc_ops", t),
            FrontendMemo::text_key("regpressure", "v", "fc_ops", "other text")
        );
    }

    #[test]
    fn capacity_is_bounded() {
        let memo = FrontendMemo::with_shards(8, 1);
        for i in 0..100u64 {
            let k = FrontendMemo::text_key("t", "v", "m", &format!("t{i}"));
            memo.insert(k, enc(vec![], i));
        }
        assert!(memo.len() <= 8, "memo grew past capacity: {}", memo.len());
        assert!(!memo.is_empty());
    }

    #[test]
    fn evictions_count_shard_wipes() {
        let memo = FrontendMemo::with_shards(2, 1);
        assert!(memo.is_empty());
        memo.insert(1, enc(vec![], 1));
        memo.insert(2, enc(vec![], 2));
        assert_eq!(memo.evictions(), 0, "filling to capacity is not an eviction");
        assert!(!memo.is_empty());
        memo.insert(3, enc(vec![], 3)); // shard full + new key → wholesale wipe
        assert_eq!(memo.evictions(), 1);
        memo.insert(3, enc(vec![], 4)); // refresh is never an eviction
        assert_eq!(memo.evictions(), 1);
        assert!(!memo.is_empty());
    }

    #[test]
    fn reinsert_same_key_does_not_clear() {
        let memo = FrontendMemo::with_shards(1, 1);
        let k = FrontendMemo::text_key("t", "v", "m", "text");
        memo.insert(k, enc(vec![1], 1));
        memo.insert(k, enc(vec![2], 2)); // refresh at cap: no wipe
        assert_eq!(memo.get(k).unwrap().key, 2);
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn shared_ids_are_not_copied() {
        let memo = FrontendMemo::new(16);
        let k = FrontendMemo::text_key("t", "v", "m", "text");
        let row = Arc::new(vec![7u32; 512]);
        memo.insert(k, CachedEncode { ids: row.clone(), key: 1 });
        let got = memo.get(k).unwrap();
        assert!(Arc::ptr_eq(&row, &got.ids), "memo hit must share, not copy");
    }
}

//! Session tier: incremental delta encoding for edit-heavy traffic.
//!
//! Autotuning loops don't just *duplicate* probes (the
//! [`super::frontend`] memo's territory) — they send long runs of
//! *near*-duplicates: the same function with one tile size, one attr,
//! one op swapped per probe. A full re-encode pays
//! lex→parse→tokenize→encode over the whole text to learn that one line
//! changed. This tier lets the client say so: `session_open` registers a
//! base text and returns a session id; `mlir_delta` sends either
//! explicit byte-range splices or the full new text (line-diffed against
//! the base here), and only the *changed* lines ever reach a lexer —
//! every unchanged line splices its cached [`IdSpan`] out of the routed
//! variant's span table (`FxHash(line bytes)` → span), byte-identical to
//! the full pipeline by construction (asserted at `session_open`).
//!
//! What lives where:
//! - per-session, variant-agnostic state ([`Session`]): the base text,
//!   its lines with per-line token counts (scheme is fixed per target,
//!   so counts are reusable across every variant of the target) — this
//!   is what routing's length decision sums without re-lexing;
//! - per-variant state (`span_table` on [`super::router::Variant`]): the
//!   line → id-span cache, per-variant because spans embed vocabulary
//!   ids.
//!
//! The store is capacity-bounded ([`SESSIONS_CAPACITY`]): opening past
//! capacity evicts the least-recently-used session (a client holding a
//! stale id gets a clean `unknown session` error and re-opens). The
//! `sessions_open` stats gauge tracks live entries.

use crate::sim::Target;
use crate::tokenizer::span::{line_hash, line_token_count, TAIL_TOKEN_COUNT};
use crate::tokenizer::Scheme;
use anyhow::{bail, Context, Result};
use fxhash::FxHashMap;
use std::sync::{Arc, Mutex};

/// Live sessions the store holds before LRU eviction kicks in. A
/// session is the base text plus per-line metadata (~2× text size);
/// 256 concurrent autotuning clients is far past the paper's traffic.
pub const SESSIONS_CAPACITY: usize = 256;

/// One indexed line of a session's base text: the raw text (splice
/// reconstruction + diffing), its span-table key, and its token count
/// under the target's scheme (variant-agnostic — what routing sums).
#[derive(Debug, Clone)]
pub struct SessionLine {
    pub text: String,
    pub hash: u64,
    pub tokens: u32,
}

/// One registered base text. `text` and `lines` sit behind `Arc` so a
/// delta snapshots them out of the store lock without copying the text.
#[derive(Debug, Clone)]
pub struct Session {
    pub target: Target,
    pub text: Arc<String>,
    pub lines: Arc<Vec<SessionLine>>,
    /// Unpadded token count of the base (line sums + tail).
    pub token_len: usize,
    /// Store tick at last touch — the LRU eviction ordering.
    last_used: u64,
}

/// One byte-range edit for [`Delta::Splices`]: replace
/// `base[start..end]` with `text`. Offsets index the session's
/// *registered base* bytes; splices must be sorted ascending and
/// non-overlapping.
#[derive(Debug, Clone)]
pub struct Splice {
    pub start: usize,
    pub end: usize,
    pub text: String,
}

/// The two wire shapes of an edit: explicit byte-range splices into the
/// base, or the full new text (the server line-diffs it against the
/// base — same cost model either way, since both reduce to "which lines
/// changed").
#[derive(Debug, Clone)]
pub enum Delta {
    Splices(Vec<Splice>),
    Full(String),
}

struct StoreInner {
    sessions: FxHashMap<u64, Session>,
    /// Session ids are sequential from 1 — deterministic for the
    /// protocol docs' verified examples.
    next_id: u64,
    tick: u64,
}

/// Capacity-bounded, LRU-evicting session registry.
pub struct SessionStore {
    inner: Mutex<StoreInner>,
    capacity: usize,
}

impl SessionStore {
    pub fn new(capacity: usize) -> SessionStore {
        SessionStore {
            inner: Mutex::new(StoreInner {
                sessions: FxHashMap::default(),
                next_id: 1,
                tick: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    /// Register a session. Returns its id plus how many older sessions
    /// were evicted to stay under capacity (the caller adjusts the
    /// `sessions_open` gauge by `1 - evicted`).
    pub fn open(
        &self,
        target: Target,
        text: Arc<String>,
        lines: Arc<Vec<SessionLine>>,
        token_len: usize,
    ) -> (u64, usize) {
        let mut inner = self.inner.lock().unwrap();
        let mut evicted = 0;
        while inner.sessions.len() >= self.capacity {
            // O(n) LRU scan — n is at most SESSIONS_CAPACITY and this
            // only runs on an open past capacity.
            let Some(&oldest) = inner
                .sessions
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(id, _)| id)
            else {
                break;
            };
            inner.sessions.remove(&oldest);
            evicted += 1;
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.tick += 1;
        let last_used = inner.tick;
        inner.sessions.insert(id, Session { target, text, lines, token_len, last_used });
        (id, evicted)
    }

    /// Snapshot a session's base (cheap: two `Arc` clones), touching its
    /// LRU stamp. `None` for an unknown or evicted id.
    pub fn snapshot(&self, id: u64) -> Option<Session> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let s = inner.sessions.get_mut(&id)?;
        s.last_used = tick;
        Some(s.clone())
    }

    /// Promote a delta's result to the session's new base (the
    /// `"rebase": true` wire flag). Concurrent rebases of one session
    /// are last-writer-wins. Returns false for an unknown id.
    pub fn rebase(
        &self,
        id: u64,
        text: Arc<String>,
        lines: Arc<Vec<SessionLine>>,
        token_len: usize,
    ) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let Some(s) = inner.sessions.get_mut(&id) else { return false };
        s.text = text;
        s.lines = lines;
        s.token_len = token_len;
        s.last_used = tick;
        true
    }

    /// Drop a session. Returns whether it existed.
    pub fn close(&self, id: u64) -> bool {
        self.inner.lock().unwrap().sessions.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Index a full text into per-line metadata: token counts via the
/// context-free line tokenizer (one count pass per line, no vocab).
/// Errors name the offending line — a text the line grammar cannot
/// handle is "not spliceable" and must be served by the full pipeline
/// instead of a session.
pub fn index_lines(text: &str, scheme: Scheme) -> Result<Vec<SessionLine>> {
    text.lines()
        .map(|line| {
            let tokens = line_token_count(line, scheme)
                .with_context(|| format!("text is not line-spliceable at {:?}", line.trim()))?;
            Ok(SessionLine {
                text: line.to_string(),
                hash: line_hash(line),
                tokens: tokens as u32,
            })
        })
        .collect()
}

/// Unpadded token count of an indexed text: line sums + the fixed tail.
pub fn indexed_token_len(lines: &[SessionLine]) -> usize {
    lines.iter().map(|l| l.tokens as usize).sum::<usize>() + TAIL_TOKEN_COUNT
}

/// Apply byte-range splices to the base text. Splices must be sorted by
/// `start` ascending, non-overlapping, in-bounds, and on UTF-8 char
/// boundaries — anything else is a clean client error, never a panic.
pub fn apply_splices(base: &str, splices: &[Splice]) -> Result<String> {
    let mut out = String::with_capacity(base.len());
    let mut cursor = 0usize;
    for (i, sp) in splices.iter().enumerate() {
        if sp.start > sp.end || sp.end > base.len() {
            bail!(
                "splice {i} range {}..{} out of bounds for base of {} bytes",
                sp.start,
                sp.end,
                base.len()
            );
        }
        if sp.start < cursor {
            bail!("splice {i} overlaps or is out of order (starts at {} before byte {cursor})",
                sp.start);
        }
        let Some(unchanged) = base.get(cursor..sp.start) else {
            bail!("splice {i} start {} is not on a UTF-8 character boundary", sp.start);
        };
        if base.get(sp.start..sp.end).is_none() {
            bail!("splice {i} end {} is not on a UTF-8 character boundary", sp.end);
        }
        out.push_str(unchanged);
        out.push_str(&sp.text);
        cursor = sp.end;
    }
    out.push_str(&base[cursor..]);
    Ok(out)
}

/// Re-index `new_text` against the old line list, reusing per-line
/// token counts for the common prefix and suffix (string compares only
/// — no lexing) and running the count pass *only* over the changed
/// middle. Returns the new line list and how many lines were counted
/// fresh.
pub fn reindex_lines(
    old: &[SessionLine],
    new_text: &str,
    scheme: Scheme,
) -> Result<(Vec<SessionLine>, usize)> {
    let new_lines: Vec<&str> = new_text.lines().collect();
    let common = old.len().min(new_lines.len());
    let mut prefix = 0;
    while prefix < common && old[prefix].text == new_lines[prefix] {
        prefix += 1;
    }
    let mut suffix = 0;
    while suffix < common - prefix
        && old[old.len() - 1 - suffix].text == new_lines[new_lines.len() - 1 - suffix]
    {
        suffix += 1;
    }
    let mut out = Vec::with_capacity(new_lines.len());
    out.extend_from_slice(&old[..prefix]);
    let changed = new_lines.len() - prefix - suffix;
    for &line in &new_lines[prefix..new_lines.len() - suffix] {
        let tokens = line_token_count(line, scheme)
            .with_context(|| format!("delta is not line-spliceable at {:?}", line.trim()))?;
        out.push(SessionLine {
            text: line.to_string(),
            hash: line_hash(line),
            tokens: tokens as u32,
        });
    }
    out.extend_from_slice(&old[old.len() - suffix..]);
    Ok((out, changed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(text: &str) -> SessionLine {
        SessionLine { text: text.to_string(), hash: line_hash(text), tokens: 1 }
    }

    #[test]
    fn apply_splices_replaces_ranges_in_order() {
        let base = "abc def ghi";
        let out = apply_splices(
            base,
            &[
                Splice { start: 0, end: 3, text: "XY".into() },
                Splice { start: 4, end: 7, text: "Z".into() },
            ],
        )
        .unwrap();
        assert_eq!(out, "XY Z ghi");
        // Pure insert (empty range) and pure delete (empty text).
        assert_eq!(
            apply_splices(base, &[Splice { start: 3, end: 3, text: "!".into() }]).unwrap(),
            "abc! def ghi"
        );
        assert_eq!(
            apply_splices(base, &[Splice { start: 3, end: 7, text: String::new() }]).unwrap(),
            "abc ghi"
        );
        // Empty splice list reproduces the base.
        assert_eq!(apply_splices(base, &[]).unwrap(), base);
    }

    #[test]
    fn apply_splices_rejects_bad_ranges() {
        let base = "héllo"; // 'é' is 2 bytes: 1..3
        assert!(apply_splices(base, &[Splice { start: 2, end: 2, text: "x".into() }])
            .unwrap_err()
            .to_string()
            .contains("character boundary"));
        assert!(apply_splices(base, &[Splice { start: 0, end: 99, text: "x".into() }])
            .unwrap_err()
            .to_string()
            .contains("out of bounds"));
        assert!(apply_splices(base, &[Splice { start: 4, end: 3, text: "x".into() }])
            .unwrap_err()
            .to_string()
            .contains("out of bounds"));
        // Overlapping / out-of-order pairs.
        let overlapping = [
            Splice { start: 0, end: 4, text: "x".into() },
            Splice { start: 3, end: 5, text: "y".into() },
        ];
        assert!(apply_splices("abcdef", &overlapping)
            .unwrap_err()
            .to_string()
            .contains("overlaps"));
    }

    #[test]
    fn reindex_recounts_only_the_changed_middle() {
        let old = vec![line("a"), line("b"), line("c"), line("d")];
        // Replace one middle line: `}` is a valid 0-token line, so the
        // count pass succeeds exactly once.
        let (new, changed) = reindex_lines(&old, "a\n}\nc\nd", Scheme::OpsOnly).unwrap();
        assert_eq!(changed, 1);
        assert_eq!(new.len(), 4);
        assert_eq!(new[1].text, "}");
        assert_eq!(new[1].tokens, 0);
        // Untouched lines keep their (deliberately fake) cached counts —
        // proof they were never re-counted.
        assert_eq!(new[0].tokens, 1);
        assert_eq!(new[3].tokens, 1);

        // Pure insert: every old line is reused.
        let (new, changed) = reindex_lines(&old, "a\nb\n}\nc\nd", Scheme::OpsOnly).unwrap();
        assert_eq!((new.len(), changed), (5, 1));
        // Pure delete: nothing is recounted at all.
        let (new, changed) = reindex_lines(&old, "a\nc\nd", Scheme::OpsOnly).unwrap();
        assert_eq!((new.len(), changed), (3, 0));
        // Identical text: no work.
        let (_, changed) = reindex_lines(&old, "a\nb\nc\nd", Scheme::OpsOnly).unwrap();
        assert_eq!(changed, 0);
    }

    #[test]
    fn reindex_errors_on_unspliceable_change() {
        let old = vec![line("a"), line("b")];
        let err = reindex_lines(&old, "a\nwat wat", Scheme::OpsOnly).unwrap_err();
        assert!(err.to_string().contains("not line-spliceable"), "{err:#}");
    }

    #[test]
    fn store_evicts_least_recently_used_past_capacity() {
        let store = SessionStore::new(2);
        let empty = || (Arc::new(String::new()), Arc::new(Vec::new()));
        let (t, l) = empty();
        let (id1, ev) = store.open(Target::RegPressure, t, l, 1);
        assert_eq!(ev, 0);
        let (t, l) = empty();
        let (id2, ev) = store.open(Target::RegPressure, t, l, 1);
        assert_eq!(ev, 0);
        assert_eq!((id1, id2), (1, 2), "ids are sequential from 1");
        // Touch id1 so id2 is the LRU entry.
        assert!(store.snapshot(id1).is_some());
        let (t, l) = empty();
        let (id3, ev) = store.open(Target::RegPressure, t, l, 1);
        assert_eq!(ev, 1);
        assert!(store.snapshot(id2).is_none(), "LRU session must be gone");
        assert!(store.snapshot(id1).is_some());
        assert!(store.snapshot(id3).is_some());
        assert_eq!(store.len(), 2);
        // Close is idempotent-ish: second close reports absence.
        assert!(store.close(id1));
        assert!(!store.close(id1));
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn rebase_swaps_the_base_for_future_snapshots() {
        let store = SessionStore::new(4);
        let (id, _) = store.open(
            Target::RegPressure,
            Arc::new("old".to_string()),
            Arc::new(vec![line("old")]),
            2,
        );
        assert!(store.rebase(
            id,
            Arc::new("new".to_string()),
            Arc::new(vec![line("new")]),
            3
        ));
        let snap = store.snapshot(id).unwrap();
        assert_eq!(snap.text.as_str(), "new");
        assert_eq!(snap.token_len, 3);
        assert!(!store.rebase(99, Arc::new(String::new()), Arc::new(Vec::new()), 0));
    }
}

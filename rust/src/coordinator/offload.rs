//! Compute offload pool: gets model execution off the IO threads.
//!
//! The event loop in [`super::server`] answers most lines without ever
//! blocking — cache hits, memo hits, stats, session bookkeeping. But a
//! cache miss executes a model (milliseconds under load) and a cluster
//! forward waits on a peer (up to the remote-get timeout), and before
//! this module existed both ran *on the IO thread*, stalling every
//! readable socket that loop owns. The fix is a small, bounded
//! request-worker pool:
//!
//! - IO threads classify each line with [`LineService::would_block`].
//!   Lines that stay cheap are answered inline exactly as before.
//! - Would-block lines become a [`Job`] on the pool's MPMC queue. A
//!   worker re-executes the line via [`LineService::handle`] (the same
//!   entry point the inline path uses, so responses are byte-identical),
//!   renders the response, and pushes a [`Completion`] into the owning
//!   loop's [`CompletionInbox`], ringing that loop's existing eventfd
//!   doorbell.
//! - The owning loop drains completions in its doorbell phase, validates
//!   the `(conn, gen, seq)` stamp against the connection slot (slots are
//!   recycled; `gen` detects reuse), appends the rendered bytes to the
//!   write buffer, and resumes parsing that connection's backlog.
//!
//! The queue is bounded: when it is full, `submit` hands the job back
//! and the caller answers inline — the system degrades to exactly the
//! pre-offload behavior instead of queueing without limit. Per-connection
//! response ordering is preserved by the server keeping at most ONE
//! outstanding offloaded line per connection and not parsing past it.
//!
//! The pool speaks to the service through the [`LineService`] trait
//! rather than `Service` directly so tests can drive it with a fake
//! (e.g. a deliberately slow head) without building model artifacts.

use super::stats::ServiceStats;
use crate::json::Json;
use minipoll::EventFd;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// The slice of a service the offload plane needs: classify a line,
/// execute it, and account for it. Implemented by the real `Service`
/// (via `handle_line`) and by test fakes.
pub trait LineService: Send + Sync {
    /// The stats sink the pool maintains its gauges/counters on.
    fn stats(&self) -> &ServiceStats;

    /// Would answering this line block the calling thread (model
    /// execution, peer wait)? Advisory: a wrong answer costs latency,
    /// never correctness — both paths run the same `handle`.
    fn would_block(&self, line: &str) -> bool;

    /// Execute one request line to a response. Must be safe to call
    /// from any thread.
    fn handle(&self, line: &str) -> Json;
}

/// One would-block line handed to the pool, stamped with enough to
/// route its response back to the right connection slot — and to detect
/// that the slot was recycled while the job was in flight.
pub struct Job {
    /// The raw request line (no trailing newline).
    pub line: String,
    /// Where the rendered response goes: the owning IO loop's inbox.
    pub inbox: Arc<CompletionInbox>,
    /// Connection slab index on the owning loop.
    pub conn: usize,
    /// Connection generation; mismatch means the slot was reused.
    pub gen: u64,
    /// Per-connection line sequence number, for debug assertions.
    pub seq: u64,
}

/// A rendered response on its way back to the IO loop: the exact bytes
/// (JSON line + `\n`) the inline path would have written.
pub struct Completion {
    pub conn: usize,
    pub gen: u64,
    pub seq: u64,
    pub bytes: Vec<u8>,
}

/// Per-IO-loop return path: workers push rendered completions here and
/// ring the loop's doorbell; the loop drains in its doorbell phase.
/// Shares the loop's existing connection-handoff eventfd — one wakeup
/// source per loop, not two.
pub struct CompletionInbox {
    done: Mutex<Vec<Completion>>,
    doorbell: Arc<EventFd>,
}

impl CompletionInbox {
    pub fn new(doorbell: Arc<EventFd>) -> CompletionInbox {
        CompletionInbox { done: Mutex::new(Vec::new()), doorbell }
    }

    /// Deliver a completion and wake the owning loop.
    pub fn push(&self, c: Completion) {
        self.done.lock().unwrap().push(c);
        self.doorbell.signal();
    }

    /// Take everything delivered so far (called from the owning loop).
    pub fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.done.lock().unwrap())
    }
}

struct Queue {
    jobs: VecDeque<Job>,
    closed: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    ready: Condvar,
    capacity: usize,
    svc: Arc<dyn LineService>,
}

/// Bounded MPMC request-worker pool. `--request-workers N` spawns one;
/// N = 0 means no pool and the server answers everything inline.
pub struct OffloadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

/// Queue slots per worker: deep enough to absorb a burst, shallow
/// enough that a stuck backend pushes load back to the inline path
/// (where it is at least visible as `io_stall_ns`) instead of building
/// an unbounded backlog.
const QUEUE_SLOTS_PER_WORKER: usize = 64;

impl OffloadPool {
    /// Spawn `workers` threads executing would-block lines for `svc`.
    /// `workers` must be ≥ 1 — a poolless server simply has no
    /// `OffloadPool` at all.
    pub fn start(svc: Arc<dyn LineService>, workers: usize) -> Arc<OffloadPool> {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            capacity: workers * QUEUE_SLOTS_PER_WORKER,
            svc,
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("request-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning request worker")
            })
            .collect();
        Arc::new(OffloadPool { shared, workers: Mutex::new(handles) })
    }

    /// Hand a job to the pool. On success the job is counted
    /// (`offloaded_misses`, `offload_queue_depth`) and a worker will
    /// deliver its completion. A full or closed queue returns the job
    /// back so the caller can answer inline — bounded means bounded.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed || q.jobs.len() >= self.shared.capacity {
            return Err(job);
        }
        q.jobs.push_back(job);
        drop(q);
        let stats = self.shared.svc.stats();
        stats.offloaded_misses.fetch_add(1, Ordering::Relaxed);
        stats.offload_queue_depth.fetch_add(1, Ordering::Relaxed);
        self.shared.ready.notify_one();
        Ok(())
    }

    /// Close the queue and join the workers. Already-queued jobs are
    /// drained and their completions delivered first; new submits are
    /// refused. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.ready.notify_all();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for OffloadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.closed {
                    return;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let stats = shared.svc.stats();
        stats.offload_queue_depth.fetch_sub(1, Ordering::Relaxed);
        // Same entry point, same rendering as the inline path: the
        // response bytes are identical whichever thread produced them.
        let resp = shared.svc.handle(&job.line);
        let mut bytes = Vec::with_capacity(128);
        resp.write_to(&mut bytes).expect("buffer write");
        bytes.push(b'\n');
        job.inbox.push(Completion { conn: job.conn, gen: job.gen, seq: job.seq, bytes });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    /// Artifact-free stand-in: echoes the line back, optionally slowly.
    struct Fake {
        stats: ServiceStats,
        delay: Duration,
    }

    impl Fake {
        fn fast() -> Arc<Fake> {
            Arc::new(Fake { stats: ServiceStats::default(), delay: Duration::ZERO })
        }

        fn slow(delay: Duration) -> Arc<Fake> {
            Arc::new(Fake { stats: ServiceStats::default(), delay })
        }
    }

    impl LineService for Fake {
        fn stats(&self) -> &ServiceStats {
            &self.stats
        }

        fn would_block(&self, line: &str) -> bool {
            line.contains("slow")
        }

        fn handle(&self, line: &str) -> Json {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Json::obj().with("echo", Json::str(line))
        }
    }

    fn inbox() -> Arc<CompletionInbox> {
        Arc::new(CompletionInbox::new(Arc::new(EventFd::new().unwrap())))
    }

    fn job(inbox: &Arc<CompletionInbox>, line: &str, seq: u64) -> Job {
        Job { line: line.to_string(), inbox: inbox.clone(), conn: 3, gen: 9, seq }
    }

    /// Drain the inbox until `n` completions arrive or the deadline
    /// passes (tests fail loudly instead of hanging).
    fn collect(inbox: &CompletionInbox, n: usize) -> Vec<Completion> {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut got = Vec::new();
        while got.len() < n {
            got.extend(inbox.drain());
            assert!(Instant::now() < deadline, "timed out: {}/{n} completions", got.len());
            std::thread::sleep(Duration::from_millis(1));
        }
        got
    }

    #[test]
    fn single_worker_preserves_submit_order_and_renders_newline_terminated_json() {
        let svc = Fake::fast();
        let pool = OffloadPool::start(svc.clone(), 1);
        let ib = inbox();
        for seq in 0..3u64 {
            pool.submit(job(&ib, &format!("line-{seq}"), seq)).map_err(|_| ()).unwrap();
        }
        let got = collect(&ib, 3);
        for (i, c) in got.iter().enumerate() {
            assert_eq!(c.seq, i as u64, "one worker must preserve submit order");
            assert_eq!(c.conn, 3);
            assert_eq!(c.gen, 9);
            assert_eq!(*c.bytes.last().unwrap(), b'\n');
            let text = std::str::from_utf8(&c.bytes).unwrap();
            assert!(text.contains(&format!("line-{i}")), "bad render: {text}");
        }
        // The doorbell accumulated at least one signal per push batch.
        pool.shutdown();
        assert_eq!(svc.stats.offloaded_misses.load(Ordering::Relaxed), 3);
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn full_queue_hands_the_job_back() {
        // One worker stuck on a slow job; fill the queue behind it.
        let svc = Fake::slow(Duration::from_millis(200));
        let pool = OffloadPool::start(svc.clone(), 1);
        let ib = inbox();
        let cap = QUEUE_SLOTS_PER_WORKER;
        // The worker may dequeue a couple of jobs while we fill, so
        // submit until the first refusal; it must come within cap + 8
        // tries (each dequeued job parks the worker for 200ms).
        let mut refused = None;
        for seq in 0..(cap as u64 + 8) {
            if let Err(back) = pool.submit(job(&ib, "slow", seq)) {
                refused = Some(back);
                break;
            }
        }
        let back = refused.expect("bounded queue never refused");
        assert_eq!(back.line, "slow", "refused job must come back intact");
        assert_eq!(back.inbox.drain().len(), 0, "refused job must not complete");
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_returning() {
        let svc = Fake::slow(Duration::from_millis(5));
        let pool = OffloadPool::start(svc.clone(), 2);
        let ib = inbox();
        for seq in 0..8u64 {
            pool.submit(job(&ib, "x", seq)).map_err(|_| ()).unwrap();
        }
        pool.shutdown();
        // Everything accepted before close was executed and delivered.
        assert_eq!(ib.drain().len(), 8);
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
        // And a post-shutdown submit is refused, not lost.
        assert!(pool.submit(job(&ib, "late", 99)).is_err());
    }

    #[test]
    fn completions_carry_the_stamp_for_slot_reuse_detection() {
        let svc = Fake::fast();
        let pool = OffloadPool::start(svc, 1);
        let ib = inbox();
        pool.submit(Job { line: "a".into(), inbox: ib.clone(), conn: 17, gen: 4, seq: 2 })
            .map_err(|_| ())
            .unwrap();
        let got = collect(&ib, 1);
        assert_eq!((got[0].conn, got[0].gen, got[0].seq), (17, 4, 2));
        pool.shutdown();
    }
}

//! Compute offload pool: gets model execution off the IO threads.
//!
//! The event loop in [`super::server`] answers most lines without ever
//! blocking — cache hits, memo hits, stats, session bookkeeping. But a
//! cache miss executes a model (milliseconds under load) and a cluster
//! forward waits on a peer (up to the remote-get timeout), and before
//! this module existed both ran *on the IO thread*, stalling every
//! readable socket that loop owns. The fix is a small, bounded
//! request-worker pool:
//!
//! - IO threads classify each line with [`LineService::would_block`].
//!   Lines that stay cheap are answered inline exactly as before.
//! - Would-block lines become a [`Job`] on the pool's MPMC queue. A
//!   worker re-executes the line via [`LineService::handle`] (the same
//!   entry point the inline path uses, so responses are byte-identical),
//!   renders the response, and pushes a [`Completion`] into the owning
//!   loop's [`CompletionInbox`], ringing that loop's existing eventfd
//!   doorbell.
//! - The owning loop drains completions in its doorbell phase, validates
//!   the `(conn, gen, seq)` stamp against the connection slot (slots are
//!   recycled; `gen` detects reuse), appends the rendered bytes to the
//!   write buffer, and resumes parsing that connection's backlog.
//!
//! The queue is bounded: when it is full, `submit` hands the job back
//! ([`SubmitError::Full`]) and the caller answers inline — the system
//! degrades to exactly the pre-offload behavior instead of queueing
//! without limit. Per-connection response ordering is preserved by the
//! server keeping at most ONE outstanding offloaded line per connection
//! and not parsing past it.
//!
//! Queueing is *weighted-fair* across tenants, not FIFO across the
//! whole pool: each [`Job`] carries a tenant key (the wire `tenant`
//! field, falling back to a per-connection key), jobs wait in their
//! tenant's own FIFO queue, and workers drain tenants round-robin — a
//! tenant flooding misses waits behind its own backlog while a tenant
//! with one queued job is served within one rotation. Because the
//! server keeps at most one offloaded line in flight per connection,
//! untenanted traffic (every connection its own key, at most one job
//! each) drains in exactly the old FIFO arrival order — the fair queue
//! is behavior-identical until tenants actually share a key. An
//! optional per-tenant in-flight cap (`--tenant-inflight`) bounds how
//! many jobs one tenant may have queued + executing; a saturated
//! tenant's submit returns [`SubmitError::TenantSaturated`] so the
//! server can answer a typed `overloaded` error instead of letting one
//! tenant monopolize every worker.
//!
//! The pool speaks to the service through the [`LineService`] trait
//! rather than `Service` directly so tests can drive it with a fake
//! (e.g. a deliberately slow head) without building model artifacts.

use super::stats::ServiceStats;
use crate::json::Json;
use minipoll::EventFd;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// The slice of a service the offload plane needs: classify a line,
/// execute it, and account for it. Implemented by the real `Service`
/// (via `handle_line`) and by test fakes.
pub trait LineService: Send + Sync {
    /// The stats sink the pool maintains its gauges/counters on.
    fn stats(&self) -> &ServiceStats;

    /// Would answering this line block the calling thread (model
    /// execution, peer wait)? Advisory: a wrong answer costs latency,
    /// never correctness — both paths run the same `handle`.
    fn would_block(&self, line: &str) -> bool;

    /// Execute one request line to a response. Must be safe to call
    /// from any thread.
    fn handle(&self, line: &str) -> Json;

    /// Deadline-shedding probe, consulted at admission when the server
    /// runs with `--shed-deadlines`: `Some(response)` means this line's
    /// `budget_us` is already unmeetable and the returned rejection
    /// (a `shed_deadline` error echoing the request id) should be
    /// written instead of processing the line. The default never sheds,
    /// so fakes and pre-tenancy services are unaffected.
    fn shed(&self, _line: &str) -> Option<Json> {
        None
    }
}

/// One would-block line handed to the pool, stamped with enough to
/// route its response back to the right connection slot — and to detect
/// that the slot was recycled while the job was in flight.
pub struct Job {
    /// The raw request line (no trailing newline).
    pub line: String,
    /// Where the rendered response goes: the owning IO loop's inbox.
    pub inbox: Arc<CompletionInbox>,
    /// Connection slab index on the owning loop.
    pub conn: usize,
    /// Connection generation; mismatch means the slot was reused.
    pub gen: u64,
    /// Per-connection line sequence number, for debug assertions.
    pub seq: u64,
    /// Fair-queueing key: the request's `tenant` field when present,
    /// else a per-connection key. Jobs sharing a tenant share one FIFO
    /// queue (and one in-flight cap); distinct tenants drain
    /// round-robin.
    pub tenant: String,
}

/// A rendered response on its way back to the IO loop: the exact bytes
/// (JSON line + `\n`) the inline path would have written.
pub struct Completion {
    pub conn: usize,
    pub gen: u64,
    pub seq: u64,
    pub bytes: Vec<u8>,
}

/// Per-IO-loop return path: workers push rendered completions here and
/// ring the loop's doorbell; the loop drains in its doorbell phase.
/// Shares the loop's existing connection-handoff eventfd — one wakeup
/// source per loop, not two.
pub struct CompletionInbox {
    done: Mutex<Vec<Completion>>,
    doorbell: Arc<EventFd>,
}

impl CompletionInbox {
    pub fn new(doorbell: Arc<EventFd>) -> CompletionInbox {
        CompletionInbox { done: Mutex::new(Vec::new()), doorbell }
    }

    /// Deliver a completion and wake the owning loop.
    pub fn push(&self, c: Completion) {
        self.done.lock().unwrap().push(c);
        self.doorbell.signal();
    }

    /// Take everything delivered so far (called from the owning loop).
    pub fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.done.lock().unwrap())
    }
}

/// Why [`OffloadPool::submit`] handed a job back.
pub enum SubmitError {
    /// The pool is closed or its global queue is full: the caller
    /// should degrade to the inline path (the pre-offload behavior).
    Full(Job),
    /// The job's tenant already has its in-flight cap's worth of jobs
    /// queued or executing: the caller should answer a typed
    /// `overloaded` rejection rather than run the work anyway.
    TenantSaturated(Job),
}

/// Weighted-fair queue state: per-tenant FIFOs drained round-robin.
struct Queue {
    /// Each tenant's waiting jobs, FIFO within the tenant. A tenant is
    /// present iff it has at least one queued job.
    per_tenant: HashMap<String, VecDeque<Job>>,
    /// Round-robin drain order: tenants with queued jobs, each present
    /// exactly once. Workers pop the front tenant's oldest job and
    /// rotate the tenant to the back while it still has work.
    order: VecDeque<String>,
    /// Total queued jobs across all tenants (the bounded-capacity
    /// check, and the `offload_queue_depth` gauge's source of truth).
    queued: usize,
    /// Jobs currently executing on a worker, per tenant — the other
    /// half of the in-flight cap (queued + executing).
    executing: HashMap<String, usize>,
    closed: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    ready: Condvar,
    capacity: usize,
    /// Per-tenant cap on jobs queued + executing; 0 = uncapped.
    tenant_cap: usize,
    svc: Arc<dyn LineService>,
}

/// Bounded MPMC request-worker pool. `--request-workers N` spawns one;
/// N = 0 means no pool and the server answers everything inline.
pub struct OffloadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

/// Queue slots per worker: deep enough to absorb a burst, shallow
/// enough that a stuck backend pushes load back to the inline path
/// (where it is at least visible as `io_stall_ns`) instead of building
/// an unbounded backlog.
const QUEUE_SLOTS_PER_WORKER: usize = 64;

impl OffloadPool {
    /// Spawn `workers` threads executing would-block lines for `svc`,
    /// with no per-tenant in-flight cap. `workers` must be ≥ 1 — a
    /// poolless server simply has no `OffloadPool` at all.
    pub fn start(svc: Arc<dyn LineService>, workers: usize) -> Arc<OffloadPool> {
        OffloadPool::start_with_cap(svc, workers, 0)
    }

    /// [`OffloadPool::start`] with a per-tenant in-flight cap: a tenant
    /// may have at most `tenant_cap` jobs queued + executing (0 = no
    /// cap); submits beyond it return [`SubmitError::TenantSaturated`].
    pub fn start_with_cap(
        svc: Arc<dyn LineService>,
        workers: usize,
        tenant_cap: usize,
    ) -> Arc<OffloadPool> {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                per_tenant: HashMap::new(),
                order: VecDeque::new(),
                queued: 0,
                executing: HashMap::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity: workers * QUEUE_SLOTS_PER_WORKER,
            tenant_cap,
            svc,
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("request-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning request worker")
            })
            .collect();
        Arc::new(OffloadPool { shared, workers: Mutex::new(handles) })
    }

    /// Hand a job to the pool. On success the job is counted
    /// (`offloaded_misses`, `offload_queue_depth`) and a worker will
    /// deliver its completion. A full or closed queue returns the job
    /// back ([`SubmitError::Full`]) so the caller can answer inline —
    /// bounded means bounded — and a tenant at its in-flight cap gets
    /// [`SubmitError::TenantSaturated`] so the caller can reject it
    /// with a typed `overloaded` error.
    pub fn submit(&self, job: Job) -> Result<(), SubmitError> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed || q.queued >= self.shared.capacity {
            return Err(SubmitError::Full(job));
        }
        if self.shared.tenant_cap > 0 {
            let busy = q.executing.get(&job.tenant).copied().unwrap_or(0)
                + q.per_tenant.get(&job.tenant).map_or(0, VecDeque::len);
            if busy >= self.shared.tenant_cap {
                return Err(SubmitError::TenantSaturated(job));
            }
        }
        // The invariant "present in `per_tenant` iff it has queued
        // jobs" (workers remove drained entries) makes the order check
        // a key probe.
        if !q.per_tenant.contains_key(&job.tenant) {
            q.order.push_back(job.tenant.clone());
        }
        let tenant = job.tenant.clone();
        q.per_tenant.entry(tenant).or_default().push_back(job);
        q.queued += 1;
        drop(q);
        let stats = self.shared.svc.stats();
        stats.offloaded_misses.fetch_add(1, Ordering::Relaxed);
        stats.offload_queue_depth.fetch_add(1, Ordering::Relaxed);
        self.shared.ready.notify_one();
        Ok(())
    }

    /// Close the queue and join the workers. Already-queued jobs are
    /// drained and their completions delivered first; new submits are
    /// refused. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.ready.notify_all();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for OffloadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Round-robin over tenants: take the front tenant's
                // oldest job; a tenant with more work rotates to the
                // back of the order so its backlog waits one turn per
                // competing tenant, not zero.
                if let Some(tenant) = q.order.pop_front() {
                    let fifo = q.per_tenant.get_mut(&tenant).expect("ordered tenant queued");
                    let job = fifo.pop_front().expect("ordered tenant nonempty");
                    if fifo.is_empty() {
                        q.per_tenant.remove(&tenant);
                    } else {
                        q.order.push_back(tenant.clone());
                    }
                    q.queued -= 1;
                    *q.executing.entry(tenant).or_insert(0) += 1;
                    break job;
                }
                if q.closed {
                    return;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let stats = shared.svc.stats();
        stats.offload_queue_depth.fetch_sub(1, Ordering::Relaxed);
        // Same entry point, same rendering as the inline path: the
        // response bytes are identical whichever thread produced them.
        let resp = shared.svc.handle(&job.line);
        let mut bytes = Vec::with_capacity(128);
        resp.write_to(&mut bytes).expect("buffer write");
        bytes.push(b'\n');
        // Release the tenant's in-flight slot BEFORE delivering the
        // completion: anyone who has observed the response must be able
        // to submit the tenant's next job without a spurious
        // saturation.
        {
            let mut q = shared.queue.lock().unwrap();
            if let Some(n) = q.executing.get_mut(&job.tenant) {
                *n -= 1;
                if *n == 0 {
                    q.executing.remove(&job.tenant);
                }
            }
        }
        job.inbox.push(Completion { conn: job.conn, gen: job.gen, seq: job.seq, bytes });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    /// Artifact-free stand-in: echoes the line back, optionally slowly.
    struct Fake {
        stats: ServiceStats,
        delay: Duration,
    }

    impl Fake {
        fn fast() -> Arc<Fake> {
            Arc::new(Fake { stats: ServiceStats::default(), delay: Duration::ZERO })
        }

        fn slow(delay: Duration) -> Arc<Fake> {
            Arc::new(Fake { stats: ServiceStats::default(), delay })
        }
    }

    impl LineService for Fake {
        fn stats(&self) -> &ServiceStats {
            &self.stats
        }

        fn would_block(&self, line: &str) -> bool {
            line.contains("slow")
        }

        fn handle(&self, line: &str) -> Json {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Json::obj().with("echo", Json::str(line))
        }
    }

    fn inbox() -> Arc<CompletionInbox> {
        Arc::new(CompletionInbox::new(Arc::new(EventFd::new().unwrap())))
    }

    fn job(inbox: &Arc<CompletionInbox>, line: &str, seq: u64) -> Job {
        tenant_job(inbox, line, seq, "t0")
    }

    fn tenant_job(inbox: &Arc<CompletionInbox>, line: &str, seq: u64, tenant: &str) -> Job {
        Job {
            line: line.to_string(),
            inbox: inbox.clone(),
            conn: 3,
            gen: 9,
            seq,
            tenant: tenant.to_string(),
        }
    }

    /// Drain the inbox until `n` completions arrive or the deadline
    /// passes (tests fail loudly instead of hanging).
    fn collect(inbox: &CompletionInbox, n: usize) -> Vec<Completion> {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut got = Vec::new();
        while got.len() < n {
            got.extend(inbox.drain());
            assert!(Instant::now() < deadline, "timed out: {}/{n} completions", got.len());
            std::thread::sleep(Duration::from_millis(1));
        }
        got
    }

    #[test]
    fn single_worker_preserves_submit_order_and_renders_newline_terminated_json() {
        let svc = Fake::fast();
        let pool = OffloadPool::start(svc.clone(), 1);
        let ib = inbox();
        for seq in 0..3u64 {
            pool.submit(job(&ib, &format!("line-{seq}"), seq)).map_err(|_| ()).unwrap();
        }
        let got = collect(&ib, 3);
        for (i, c) in got.iter().enumerate() {
            assert_eq!(c.seq, i as u64, "one worker must preserve submit order");
            assert_eq!(c.conn, 3);
            assert_eq!(c.gen, 9);
            assert_eq!(*c.bytes.last().unwrap(), b'\n');
            let text = std::str::from_utf8(&c.bytes).unwrap();
            assert!(text.contains(&format!("line-{i}")), "bad render: {text}");
        }
        // The doorbell accumulated at least one signal per push batch.
        pool.shutdown();
        assert_eq!(svc.stats.offloaded_misses.load(Ordering::Relaxed), 3);
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn full_queue_hands_the_job_back() {
        // One worker stuck on a slow job; fill the queue behind it.
        let svc = Fake::slow(Duration::from_millis(200));
        let pool = OffloadPool::start(svc.clone(), 1);
        let ib = inbox();
        let cap = QUEUE_SLOTS_PER_WORKER;
        // The worker may dequeue a couple of jobs while we fill, so
        // submit until the first refusal; it must come within cap + 8
        // tries (each dequeued job parks the worker for 200ms).
        let mut refused = None;
        for seq in 0..(cap as u64 + 8) {
            if let Err(e) = pool.submit(job(&ib, "slow", seq)) {
                refused = Some(e);
                break;
            }
        }
        let back = match refused.expect("bounded queue never refused") {
            SubmitError::Full(back) => back,
            SubmitError::TenantSaturated(_) => panic!("uncapped pool reported saturation"),
        };
        assert_eq!(back.line, "slow", "refused job must come back intact");
        assert_eq!(back.inbox.drain().len(), 0, "refused job must not complete");
        pool.shutdown();
    }

    #[test]
    fn round_robin_interleaves_tenants_instead_of_fifo() {
        // One worker parked on a sacrificial job while two tenants
        // queue 3 jobs each, tenant A's all submitted before tenant
        // B's. FIFO would answer A,A,A,B,B,B; the fair queue must
        // alternate after each tenant's first turn.
        let svc = Fake::slow(Duration::from_millis(60));
        let pool = OffloadPool::start(svc, 1);
        let ib = inbox();
        pool.submit(tenant_job(&ib, "slow warmup", 0, "warm")).map_err(|_| ()).unwrap();
        // The worker is now (or imminently) busy for 60ms; everything
        // below lands in the queue before it next pops.
        for seq in 0..3u64 {
            pool.submit(tenant_job(&ib, &format!("slow a{seq}"), 10 + seq, "a"))
                .map_err(|_| ())
                .unwrap();
        }
        for seq in 0..3u64 {
            pool.submit(tenant_job(&ib, &format!("slow b{seq}"), 20 + seq, "b"))
                .map_err(|_| ())
                .unwrap();
        }
        let got = collect(&ib, 7);
        let order: Vec<u64> = got.iter().map(|c| c.seq).skip(1).collect();
        assert_eq!(
            order,
            vec![10, 20, 11, 21, 12, 22],
            "tenants must drain round-robin, one job per turn"
        );
        pool.shutdown();
    }

    #[test]
    fn tenant_inflight_cap_saturates_only_the_offender() {
        // Cap 1, one worker stuck on tenant A's first job: A's second
        // submit is saturated (typed rejection), B's first is accepted.
        let svc = Fake::slow(Duration::from_millis(150));
        let pool = OffloadPool::start_with_cap(svc.clone(), 1, 1);
        let ib = inbox();
        pool.submit(tenant_job(&ib, "slow a0", 0, "a")).map_err(|_| ()).unwrap();
        // Regardless of whether a0 is still queued or already
        // executing, tenant A is at its cap of 1.
        let refused = pool.submit(tenant_job(&ib, "slow a1", 1, "a"));
        match refused {
            Err(SubmitError::TenantSaturated(back)) => assert_eq!(back.line, "slow a1"),
            Err(SubmitError::Full(_)) => panic!("near-empty queue reported Full"),
            Ok(()) => panic!("cap 1 accepted a second in-flight job for one tenant"),
        }
        pool.submit(tenant_job(&ib, "slow b0", 2, "b"))
            .map_err(|_| ())
            .expect("an idle tenant must not be blocked by another's cap");
        // Once A's backlog fully drains, A is admitted again.
        let got = collect(&ib, 2);
        assert_eq!(got.len(), 2);
        pool.submit(tenant_job(&ib, "slow a2", 3, "a"))
            .map_err(|_| ())
            .expect("cap must release after the tenant's jobs finish");
        pool.shutdown();
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_returning() {
        let svc = Fake::slow(Duration::from_millis(5));
        let pool = OffloadPool::start(svc.clone(), 2);
        let ib = inbox();
        for seq in 0..8u64 {
            pool.submit(job(&ib, "x", seq)).map_err(|_| ()).unwrap();
        }
        pool.shutdown();
        // Everything accepted before close was executed and delivered.
        assert_eq!(ib.drain().len(), 8);
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
        // And a post-shutdown submit is refused, not lost.
        assert!(pool.submit(job(&ib, "late", 99)).is_err());
    }

    #[test]
    fn completions_carry_the_stamp_for_slot_reuse_detection() {
        let svc = Fake::fast();
        let pool = OffloadPool::start(svc, 1);
        let ib = inbox();
        pool.submit(Job { line: "a".into(), inbox: ib.clone(), conn: 17, gen: 4, seq: 2 })
            .map_err(|_| ())
            .unwrap();
        let got = collect(&ib, 1);
        assert_eq!((got[0].conn, got[0].gen, got[0].seq), (17, 4, 2));
        pool.shutdown();
    }
}

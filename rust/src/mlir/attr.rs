//! Operation attributes (the `{...}` dictionary on an MLIR op).

use std::fmt;

/// Attribute value. Covers everything the `xpu`/`affine` subset needs.
#[derive(Debug, Clone, PartialEq)]
pub enum Attr {
    Int(i64),
    Float(f64),
    Str(String),
    IntArray(Vec<i64>),
    Bool(bool),
}

impl Attr {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Attr::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_int_array(&self) -> Option<&[i64]> {
        match self {
            Attr::IntArray(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attr::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Attr::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Attr::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Attr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Attr::Int(v) => write!(f, "{v}"),
            // Always keep a decimal point so the parser can distinguish
            // floats from ints on the way back in.
            Attr::Float(v) => {
                if v.fract() == 0.0 && v.is_finite() {
                    write!(f, "{v:.1}")
                } else {
                    write!(f, "{v}")
                }
            }
            Attr::Str(s) => write!(f, "\"{s}\""),
            Attr::IntArray(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Attr::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Ordered attribute dictionary. Order is preserved so printing is
/// deterministic (important: the tokenizer consumes printed text).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attrs(pub Vec<(String, Attr)>);

impl Attrs {
    pub fn new() -> Self {
        Attrs(Vec::new())
    }

    pub fn with(mut self, key: &str, value: Attr) -> Self {
        self.set(key, value);
        self
    }

    /// Insert or replace.
    pub fn set(&mut self, key: &str, value: Attr) {
        if let Some(slot) = self.0.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.0.push((key.to_string(), value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Attr> {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Attr::as_int)
    }

    pub fn get_int_array(&self, key: &str) -> Option<&[i64]> {
        self.get(key).and_then(Attr::as_int_array)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Attr::as_str)
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Attr::as_float)
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }
}

impl fmt::Display for Attrs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (k, v)) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{k} = {v}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attrs_set_get() {
        let mut a = Attrs::new();
        a.set("strides", Attr::IntArray(vec![2, 2]));
        a.set("axis", Attr::Int(1));
        a.set("axis", Attr::Int(3)); // replace
        assert_eq!(a.get_int("axis"), Some(3));
        assert_eq!(a.get_int_array("strides"), Some(&[2i64, 2][..]));
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn attrs_display() {
        let a = Attrs::new()
            .with("pad", Attr::IntArray(vec![1, 1]))
            .with("name", Attr::Str("conv1".into()))
            .with("eps", Attr::Float(1e-5))
            .with("keep", Attr::Bool(true));
        assert_eq!(
            a.to_string(),
            "{pad = [1, 1], name = \"conv1\", eps = 0.00001, keep = true}"
        );
    }

    #[test]
    fn float_display_keeps_point() {
        assert_eq!(Attr::Float(2.0).to_string(), "2.0");
        assert_eq!(Attr::Float(0.5).to_string(), "0.5");
    }
}

//! Core IR structures: values, operations, blocks, functions, modules —
//! plus a type-inferring builder used by the graph generators and the
//! lowering pipeline.
//!
//! Values are in SSA form (paper §2: "the defs are in SSA form"): each
//! `ValueId` is defined exactly once, either as a function/block argument
//! or as an op result.

use super::attr::Attrs;
use super::ops::{AffineOp, ArithOp, MemRefOp, OpKind, XpuOp};
use super::types::{DType, TensorType, Type};
use anyhow::{anyhow, bail, ensure, Result};

/// Index into a function's value table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub u32);

/// One operation. `region` is `Some` only for `affine.for`.
#[derive(Debug, Clone)]
pub struct Operation {
    pub kind: OpKind,
    pub operands: Vec<ValueId>,
    pub results: Vec<ValueId>,
    pub attrs: Attrs,
    pub region: Option<Block>,
}

/// A straight-line list of operations. `args` holds block arguments (the
/// induction variable for an `affine.for` body).
#[derive(Debug, Clone, Default)]
pub struct Block {
    pub args: Vec<ValueId>,
    pub ops: Vec<Operation>,
}

impl Block {
    /// Recursive op count (regions included).
    pub fn num_ops(&self) -> usize {
        self.ops
            .iter()
            .map(|op| 1 + op.region.as_ref().map_or(0, Block::num_ops))
            .sum()
    }
}

/// A function: the unit the paper's cost model scores (one dataflow
/// (sub)graph per function).
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    /// Types of all values, indexed by `ValueId`.
    values: Vec<Type>,
    /// Printable names for all values (`arg0`, `0`, `1`, ...).
    names: Vec<String>,
    /// Number of leading values that are function arguments.
    num_args: usize,
    pub ret: Vec<ValueId>,
    pub body: Block,
}

impl Function {
    pub fn num_args(&self) -> usize {
        self.num_args
    }

    pub fn arg_ids(&self) -> impl Iterator<Item = ValueId> {
        (0..self.num_args as u32).map(ValueId)
    }

    pub fn value_type(&self, id: ValueId) -> &Type {
        &self.values[id.0 as usize]
    }

    pub fn value_name(&self, id: ValueId) -> &str {
        &self.names[id.0 as usize]
    }

    pub fn num_values(&self) -> usize {
        self.values.len()
    }

    pub fn arg_types(&self) -> Vec<&Type> {
        (0..self.num_args).map(|i| &self.values[i]).collect()
    }

    pub fn ret_types(&self) -> Vec<&Type> {
        self.ret.iter().map(|&id| self.value_type(id)).collect()
    }

    /// Recursive op count, excluding the terminating `func.return`.
    pub fn num_ops(&self) -> usize {
        self.body.num_ops().saturating_sub(1)
    }

    /// Depth-first walk over all operations (outer before region body).
    pub fn walk<F: FnMut(&Operation, usize)>(&self, f: &mut F) {
        fn go<F: FnMut(&Operation, usize)>(block: &Block, depth: usize, f: &mut F) {
            for op in &block.ops {
                f(op, depth);
                if let Some(region) = &op.region {
                    go(region, depth + 1, f);
                }
            }
        }
        go(&self.body, 0, f);
    }

    /// The flat sequence of `xpu` ops (paper's "ops-only" view source).
    pub fn xpu_ops(&self) -> Vec<XpuOp> {
        let mut out = Vec::new();
        self.walk(&mut |op, _| {
            if let OpKind::Xpu(x) = op.kind {
                out.push(x);
            }
        });
        out
    }

    /// Maximum loop-nest depth (0 for a pure dataflow function).
    pub fn max_loop_depth(&self) -> usize {
        let mut max = 0usize;
        self.walk(&mut |op, depth| {
            if matches!(op.kind, OpKind::Affine(AffineOp::For)) {
                max = max.max(depth + 1);
            }
        });
        max
    }
}

/// A module: a named set of functions (one corpus file).
#[derive(Debug, Clone, Default)]
pub struct Module {
    pub name: String,
    pub functions: Vec<Function>,
}

impl Module {
    pub fn new(name: &str) -> Self {
        Module { name: name.to_string(), functions: Vec::new() }
    }

    pub fn get(&self, name: &str) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == name)
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Type-inferring SSA function builder.
///
/// ```
/// use mlir_cost::mlir::*;
/// let mut b = FuncBuilder::new("f");
/// let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
/// let w = b.arg(Type::tensor(vec![8, 16], DType::F32));
/// let y = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
/// let r = b.xpu(XpuOp::Relu, &[y], Attrs::new()).unwrap();
/// let f = b.ret(&[r]).unwrap();
/// assert_eq!(f.num_ops(), 2);
/// ```
pub struct FuncBuilder {
    name: String,
    values: Vec<Type>,
    names: Vec<String>,
    num_args: usize,
    /// Stack of open blocks; `stack[0]` is the function body. Entries above
    /// it are open `affine.for` bodies, paired with the loop's attrs.
    stack: Vec<(Block, Option<Attrs>)>,
    next_num: u32,
    saw_op: bool,
}

impl FuncBuilder {
    pub fn new(name: &str) -> Self {
        FuncBuilder {
            name: name.to_string(),
            values: Vec::new(),
            names: Vec::new(),
            num_args: 0,
            stack: vec![(Block::default(), None)],
            next_num: 0,
            saw_op: false,
        }
    }

    /// Type of an already-created value (for generators that need to
    /// propagate shapes while building).
    pub fn value_type(&self, id: ValueId) -> &Type {
        &self.values[id.0 as usize]
    }

    /// Declare a function argument. Must precede all ops.
    pub fn arg(&mut self, ty: Type) -> ValueId {
        assert!(!self.saw_op, "arguments must be declared before ops");
        let id = ValueId(self.values.len() as u32);
        self.names.push(format!("arg{}", self.num_args));
        self.values.push(ty);
        self.num_args += 1;
        id
    }

    fn fresh(&mut self, ty: Type) -> ValueId {
        let id = ValueId(self.values.len() as u32);
        self.names.push(self.next_num.to_string());
        self.next_num += 1;
        self.values.push(ty);
        id
    }

    fn check_operands(&self, operands: &[ValueId]) -> Result<()> {
        for &v in operands {
            ensure!(
                (v.0 as usize) < self.values.len(),
                "operand %{} is not defined",
                v.0
            );
        }
        Ok(())
    }

    fn push(&mut self, op: Operation) {
        self.saw_op = true;
        self.stack.last_mut().expect("builder has an open block").0.ops.push(op);
    }

    /// Append an `xpu` op; result type is inferred and verified.
    pub fn xpu(&mut self, op: XpuOp, operands: &[ValueId], attrs: Attrs) -> Result<ValueId> {
        self.check_operands(operands)?;
        let operand_types: Vec<Type> =
            operands.iter().map(|&v| self.values[v.0 as usize].clone()).collect();
        let result_ty = op.infer_result(&operand_types, &attrs)?;
        let result = self.fresh(result_ty);
        self.push(Operation {
            kind: OpKind::Xpu(op),
            operands: operands.to_vec(),
            results: vec![result],
            attrs,
            region: None,
        });
        Ok(result)
    }

    /// Append an `arith` op over scalars.
    pub fn arith(&mut self, op: ArithOp, operands: &[ValueId], attrs: Attrs) -> Result<ValueId> {
        self.check_operands(operands)?;
        let ty = if op == ArithOp::Constant {
            ensure!(operands.is_empty(), "arith.constant takes no operands");
            let dtype = attrs
                .get_str("dtype")
                .and_then(DType::parse)
                .unwrap_or(DType::F32);
            Type::Scalar(dtype)
        } else {
            let first = operands
                .first()
                .ok_or_else(|| anyhow!("arith.{} needs operands", op.mnemonic()))?;
            let ty = self.values[first.0 as usize].clone();
            ensure!(
                matches!(ty, Type::Scalar(_)),
                "arith.{} operands must be scalar, got {ty}",
                op.mnemonic()
            );
            ty
        };
        let result = self.fresh(ty);
        self.push(Operation {
            kind: OpKind::Arith(op),
            operands: operands.to_vec(),
            results: vec![result],
            attrs,
            region: None,
        });
        Ok(result)
    }

    /// Allocate a scratchpad buffer (`memref.alloc`).
    pub fn alloc(&mut self, shape: Vec<i64>, dtype: DType) -> ValueId {
        let ty = Type::MemRef(TensorType::new(shape, dtype));
        let result = self.fresh(ty);
        self.push(Operation {
            kind: OpKind::MemRef(MemRefOp::Alloc),
            operands: vec![],
            results: vec![result],
            attrs: Attrs::new(),
            region: None,
        });
        result
    }

    /// `affine.load %m[%i...]` → scalar.
    pub fn load(&mut self, memref: ValueId, indices: &[ValueId]) -> Result<ValueId> {
        self.check_operands(&[memref])?;
        self.check_operands(indices)?;
        let dtype = self.values[memref.0 as usize]
            .as_memref()
            .ok_or_else(|| anyhow!("affine.load: operand must be a memref"))?
            .dtype;
        let result = self.fresh(Type::Scalar(dtype));
        let mut operands = vec![memref];
        operands.extend_from_slice(indices);
        self.push(Operation {
            kind: OpKind::Affine(AffineOp::Load),
            operands,
            results: vec![result],
            attrs: Attrs::new(),
            region: None,
        });
        Ok(result)
    }

    /// `affine.store %v, %m[%i...]`.
    pub fn store(&mut self, value: ValueId, memref: ValueId, indices: &[ValueId]) -> Result<()> {
        self.check_operands(&[value, memref])?;
        self.check_operands(indices)?;
        ensure!(
            self.values[memref.0 as usize].as_memref().is_some(),
            "affine.store: target must be a memref"
        );
        let mut operands = vec![value, memref];
        operands.extend_from_slice(indices);
        self.push(Operation {
            kind: OpKind::Affine(AffineOp::Store),
            operands,
            results: vec![],
            attrs: Attrs::new(),
            region: None,
        });
        Ok(())
    }

    /// Open an `affine.for lb..ub step s` body; returns the induction var.
    /// Must be matched by [`FuncBuilder::end_for`].
    pub fn begin_for(&mut self, lb: i64, ub: i64, step: i64) -> ValueId {
        assert!(step > 0, "affine.for step must be positive");
        self.saw_op = true;
        let iv = self.fresh(Type::Index);
        let attrs = Attrs::new()
            .with("lb", super::attr::Attr::Int(lb))
            .with("ub", super::attr::Attr::Int(ub))
            .with("step", super::attr::Attr::Int(step));
        self.stack.push((Block { args: vec![iv], ops: Vec::new() }, Some(attrs)));
        iv
    }

    /// Close the innermost `affine.for`.
    pub fn end_for(&mut self) -> Result<()> {
        ensure!(self.stack.len() > 1, "end_for without begin_for");
        let (mut block, attrs) = self.stack.pop().expect("stack non-empty");
        // Implicit terminator.
        if !matches!(block.ops.last().map(|o| o.kind), Some(OpKind::Affine(AffineOp::Yield))) {
            block.ops.push(Operation {
                kind: OpKind::Affine(AffineOp::Yield),
                operands: vec![],
                results: vec![],
                attrs: Attrs::new(),
                region: None,
            });
        }
        self.push(Operation {
            kind: OpKind::Affine(AffineOp::For),
            operands: vec![],
            results: vec![],
            attrs: attrs.expect("for-block carries attrs"),
            region: Some(block),
        });
        Ok(())
    }

    /// Terminate with `func.return` and produce the finished function.
    pub fn ret(mut self, results: &[ValueId]) -> Result<Function> {
        self.check_operands(results)?;
        ensure!(self.stack.len() == 1, "unclosed affine.for at function end");
        self.push(Operation {
            kind: OpKind::Return,
            operands: results.to_vec(),
            results: vec![],
            attrs: Attrs::new(),
            region: None,
        });
        let (body, _) = self.stack.pop().expect("body block");
        Ok(Function {
            name: self.name,
            values: self.values,
            names: self.names,
            num_args: self.num_args,
            ret: results.to_vec(),
            body,
        })
    }
}

/// Construct a `Function` from raw parsed pieces (used by the parser,
/// which has already resolved names to ids).
pub(crate) fn function_from_parts(
    name: String,
    values: Vec<Type>,
    names: Vec<String>,
    num_args: usize,
    ret: Vec<ValueId>,
    body: Block,
) -> Result<Function> {
    if !matches!(body.ops.last().map(|o| o.kind), Some(OpKind::Return)) {
        bail!("function @{name} does not end in func.return");
    }
    Ok(Function { name, values, names, num_args, ret, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::attr::Attr;

    #[test]
    fn build_simple_graph() {
        let mut b = FuncBuilder::new("mini");
        let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
        let w = b.arg(Type::tensor(vec![8, 16], DType::F32));
        let y = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let z = b.xpu(XpuOp::Relu, &[y], Attrs::new()).unwrap();
        let f = b.ret(&[z]).unwrap();
        assert_eq!(f.num_args(), 2);
        assert_eq!(f.num_ops(), 2);
        assert_eq!(f.value_type(z), &Type::tensor(vec![4, 16], DType::F32));
        assert_eq!(f.value_name(x), "arg0");
        assert_eq!(f.value_name(z), "1");
        assert_eq!(f.xpu_ops(), vec![XpuOp::MatMul, XpuOp::Relu]);
    }

    #[test]
    fn builder_rejects_bad_shapes() {
        let mut b = FuncBuilder::new("bad");
        let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
        let w = b.arg(Type::tensor(vec![9, 16], DType::F32));
        assert!(b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).is_err());
    }

    #[test]
    fn build_loop_nest() {
        let mut b = FuncBuilder::new("loops");
        let buf = b.alloc(vec![64, 64], DType::F32);
        let i = b.begin_for(0, 64, 1);
        let j = b.begin_for(0, 64, 1);
        let v = b.load(buf, &[i, j]).unwrap();
        let c = b
            .arith(ArithOp::Constant, &[], Attrs::new().with("value", Attr::Float(2.0)))
            .unwrap();
        let m = b.arith(ArithOp::MulF, &[v, c], Attrs::new()).unwrap();
        b.store(m, buf, &[i, j]).unwrap();
        b.end_for().unwrap();
        b.end_for().unwrap();
        let f = b.ret(&[]).unwrap();
        assert_eq!(f.max_loop_depth(), 2);
        // alloc + 2 fors + load + const + mul + store + 2 yields = 9 ops
        assert_eq!(f.num_ops(), 9);
    }

    #[test]
    fn unclosed_for_is_error() {
        let mut b = FuncBuilder::new("oops");
        b.begin_for(0, 4, 1);
        assert!(b.ret(&[]).is_err());
    }

    #[test]
    fn module_lookup() {
        let mut b = FuncBuilder::new("f1");
        let x = b.arg(Type::tensor(vec![2], DType::F32));
        let f = b.ret(&[x]).unwrap();
        let mut m = Module::new("test");
        m.functions.push(f);
        assert!(m.get("f1").is_some());
        assert!(m.get("f2").is_none());
    }
}

//! Opcode registry for the `xpu`, `affine`, `arith` and `memref` dialect
//! subset, with per-op shape/type inference.
//!
//! The `xpu` dialect is the paper's high-level dialect: each op is a whole
//! neural-net operator on tensors (`xpu.mult`, `xpu.conv2d`, ...). The
//! `affine`/`arith`/`memref` subset is what our DL-compiler lowers to on
//! the way to the accelerator ISA, and also serves the paper's "lower-level
//! dialects like affine" token-sequence experiments.

use super::attr::Attrs;
use super::types::{DType, TensorType, Type};
use anyhow::{anyhow, bail, ensure, Result};

/// High-level `xpu` dialect operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum XpuOp {
    // -- dense linear algebra -------------------------------------------
    MatMul,
    Conv2d,
    DepthwiseConv2d,
    Conv1d,
    // -- elementwise binary ---------------------------------------------
    Add,
    Sub,
    Mult,
    Div,
    Maximum,
    Minimum,
    // -- elementwise unary ----------------------------------------------
    Relu,
    Gelu,
    Sigmoid,
    Tanh,
    Erf,
    Exp,
    Sqrt,
    Rsqrt,
    Neg,
    // -- normalization / reduction --------------------------------------
    Softmax,
    BatchNorm,
    LayerNorm,
    ReduceSum,
    ReduceMax,
    ReduceMean,
    // -- pooling ----------------------------------------------------------
    MaxPool2d,
    AvgPool2d,
    GlobalAvgPool,
    // -- data movement / shape -------------------------------------------
    Concat,
    Reshape,
    Transpose,
    Broadcast,
    Slice,
    Pad,
    Upsample,
    Embedding,
    Const,
}

impl XpuOp {
    /// All ops, for vocabulary construction and property tests.
    pub const ALL: [XpuOp; 37] = [
        XpuOp::MatMul,
        XpuOp::Conv2d,
        XpuOp::DepthwiseConv2d,
        XpuOp::Conv1d,
        XpuOp::Add,
        XpuOp::Sub,
        XpuOp::Mult,
        XpuOp::Div,
        XpuOp::Maximum,
        XpuOp::Minimum,
        XpuOp::Relu,
        XpuOp::Gelu,
        XpuOp::Sigmoid,
        XpuOp::Tanh,
        XpuOp::Erf,
        XpuOp::Exp,
        XpuOp::Sqrt,
        XpuOp::Rsqrt,
        XpuOp::Neg,
        XpuOp::Softmax,
        XpuOp::BatchNorm,
        XpuOp::LayerNorm,
        XpuOp::ReduceSum,
        XpuOp::ReduceMax,
        XpuOp::ReduceMean,
        XpuOp::MaxPool2d,
        XpuOp::AvgPool2d,
        XpuOp::GlobalAvgPool,
        XpuOp::Concat,
        XpuOp::Reshape,
        XpuOp::Transpose,
        XpuOp::Broadcast,
        XpuOp::Slice,
        XpuOp::Pad,
        XpuOp::Upsample,
        XpuOp::Embedding,
        XpuOp::Const,
    ];

    /// Mnemonic without the dialect prefix (`mult`, not `xpu.mult`).
    pub fn mnemonic(self) -> &'static str {
        match self {
            XpuOp::MatMul => "matmul",
            XpuOp::Conv2d => "conv2d",
            XpuOp::DepthwiseConv2d => "depthwise_conv2d",
            XpuOp::Conv1d => "conv1d",
            XpuOp::Add => "add",
            XpuOp::Sub => "sub",
            XpuOp::Mult => "mult",
            XpuOp::Div => "div",
            XpuOp::Maximum => "maximum",
            XpuOp::Minimum => "minimum",
            XpuOp::Relu => "relu",
            XpuOp::Gelu => "gelu",
            XpuOp::Sigmoid => "sigmoid",
            XpuOp::Tanh => "tanh",
            XpuOp::Erf => "erf",
            XpuOp::Exp => "exp",
            XpuOp::Sqrt => "sqrt",
            XpuOp::Rsqrt => "rsqrt",
            XpuOp::Neg => "neg",
            XpuOp::Softmax => "softmax",
            XpuOp::BatchNorm => "batchnorm",
            XpuOp::LayerNorm => "layernorm",
            XpuOp::ReduceSum => "reduce_sum",
            XpuOp::ReduceMax => "reduce_max",
            XpuOp::ReduceMean => "reduce_mean",
            XpuOp::MaxPool2d => "maxpool2d",
            XpuOp::AvgPool2d => "avgpool2d",
            XpuOp::GlobalAvgPool => "global_avgpool",
            XpuOp::Concat => "concat",
            XpuOp::Reshape => "reshape",
            XpuOp::Transpose => "transpose",
            XpuOp::Broadcast => "broadcast",
            XpuOp::Slice => "slice",
            XpuOp::Pad => "pad",
            XpuOp::Upsample => "upsample",
            XpuOp::Embedding => "embedding",
            XpuOp::Const => "const",
        }
    }

    pub fn parse(mnemonic: &str) -> Option<XpuOp> {
        XpuOp::ALL.iter().copied().find(|op| op.mnemonic() == mnemonic)
    }

    /// Is this op elementwise (same-shape in/out, fusable)?
    pub fn is_elementwise(self) -> bool {
        matches!(
            self,
            XpuOp::Add
                | XpuOp::Sub
                | XpuOp::Mult
                | XpuOp::Div
                | XpuOp::Maximum
                | XpuOp::Minimum
                | XpuOp::Relu
                | XpuOp::Gelu
                | XpuOp::Sigmoid
                | XpuOp::Tanh
                | XpuOp::Erf
                | XpuOp::Exp
                | XpuOp::Sqrt
                | XpuOp::Rsqrt
                | XpuOp::Neg
        )
    }

    /// Ops whose inner loops contract a dimension on the MXU.
    pub fn is_contraction(self) -> bool {
        matches!(
            self,
            XpuOp::MatMul | XpuOp::Conv2d | XpuOp::DepthwiseConv2d | XpuOp::Conv1d
        )
    }
}

/// `affine` dialect subset (plus the induction-variable-free `yield`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AffineOp {
    /// `affine.for %i = lb to ub step s { ... }` — carries one region.
    For,
    /// Terminator of an `affine.for` body.
    Yield,
    /// `affine.load %memref[%i, %j]` — scalar load.
    Load,
    /// `affine.store %v, %memref[%i, %j]`.
    Store,
    /// `affine.vector_load` with a `width` attr — one vector-register load.
    VectorLoad,
    /// `affine.vector_store` with a `width` attr.
    VectorStore,
}

impl AffineOp {
    /// All ops, for vocabulary construction and table-driven id lookup.
    pub const ALL: [AffineOp; 6] = [
        AffineOp::For,
        AffineOp::Yield,
        AffineOp::Load,
        AffineOp::Store,
        AffineOp::VectorLoad,
        AffineOp::VectorStore,
    ];

    pub fn mnemonic(self) -> &'static str {
        match self {
            AffineOp::For => "for",
            AffineOp::Yield => "yield",
            AffineOp::Load => "load",
            AffineOp::Store => "store",
            AffineOp::VectorLoad => "vector_load",
            AffineOp::VectorStore => "vector_store",
        }
    }

    pub fn parse(m: &str) -> Option<AffineOp> {
        Some(match m {
            "for" => AffineOp::For,
            "yield" => AffineOp::Yield,
            "load" => AffineOp::Load,
            "store" => AffineOp::Store,
            "vector_load" => AffineOp::VectorLoad,
            "vector_store" => AffineOp::VectorStore,
            _ => return None,
        })
    }
}

/// `arith` dialect subset — scalar/vector arithmetic inside loop nests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArithOp {
    Constant,
    AddF,
    SubF,
    MulF,
    DivF,
    MaxF,
    MinF,
    /// Fused multiply-add; produced by the codegen peephole.
    Fma,
    ExpF,
    TanhF,
    ErfF,
    SqrtF,
    RsqrtF,
    NegF,
}

impl ArithOp {
    /// All ops, for vocabulary construction and table-driven id lookup.
    pub const ALL: [ArithOp; 14] = [
        ArithOp::Constant,
        ArithOp::AddF,
        ArithOp::SubF,
        ArithOp::MulF,
        ArithOp::DivF,
        ArithOp::MaxF,
        ArithOp::MinF,
        ArithOp::Fma,
        ArithOp::ExpF,
        ArithOp::TanhF,
        ArithOp::ErfF,
        ArithOp::SqrtF,
        ArithOp::RsqrtF,
        ArithOp::NegF,
    ];

    pub fn mnemonic(self) -> &'static str {
        match self {
            ArithOp::Constant => "constant",
            ArithOp::AddF => "addf",
            ArithOp::SubF => "subf",
            ArithOp::MulF => "mulf",
            ArithOp::DivF => "divf",
            ArithOp::MaxF => "maxf",
            ArithOp::MinF => "minf",
            ArithOp::Fma => "fma",
            ArithOp::ExpF => "expf",
            ArithOp::TanhF => "tanhf",
            ArithOp::ErfF => "erff",
            ArithOp::SqrtF => "sqrtf",
            ArithOp::RsqrtF => "rsqrtf",
            ArithOp::NegF => "negf",
        }
    }

    pub fn parse(m: &str) -> Option<ArithOp> {
        use ArithOp::*;
        Some(match m {
            "constant" => Constant,
            "addf" => AddF,
            "subf" => SubF,
            "mulf" => MulF,
            "divf" => DivF,
            "maxf" => MaxF,
            "minf" => MinF,
            "fma" => Fma,
            "expf" => ExpF,
            "tanhf" => TanhF,
            "erff" => ErfF,
            "sqrtf" => SqrtF,
            "rsqrtf" => RsqrtF,
            "negf" => NegF,
            _ => return None,
        })
    }
}

/// `memref` dialect subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemRefOp {
    /// Allocate a buffer in accelerator scratchpad.
    Alloc,
}

/// Every operation kind the IR can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Xpu(XpuOp),
    Affine(AffineOp),
    Arith(ArithOp),
    MemRef(MemRefOp),
    /// `func.return`.
    Return,
}

impl OpKind {
    /// Fully-qualified MLIR name, e.g. `xpu.mult`, `affine.for`.
    pub fn full_name(&self) -> String {
        match self {
            OpKind::Xpu(op) => format!("xpu.{}", op.mnemonic()),
            OpKind::Affine(op) => format!("affine.{}", op.mnemonic()),
            OpKind::Arith(op) => format!("arith.{}", op.mnemonic()),
            OpKind::MemRef(MemRefOp::Alloc) => "memref.alloc".to_string(),
            OpKind::Return => "func.return".to_string(),
        }
    }

    /// Number of distinct op kinds (size of a dense `table_index` table).
    pub const TABLE_LEN: usize =
        XpuOp::ALL.len() + AffineOp::ALL.len() + ArithOp::ALL.len() + 2;

    /// Dense index in `0..TABLE_LEN`, for table-driven lookups on the
    /// serving hot path (see `tokenizer::OpIdTable`). Relies on the
    /// sub-enums being unit-only and declared in `ALL` order, so the
    /// `as usize` discriminant doubles as the position.
    #[inline]
    pub fn table_index(&self) -> usize {
        match self {
            OpKind::Xpu(op) => *op as usize,
            OpKind::Affine(op) => XpuOp::ALL.len() + *op as usize,
            OpKind::Arith(op) => XpuOp::ALL.len() + AffineOp::ALL.len() + *op as usize,
            OpKind::MemRef(MemRefOp::Alloc) => OpKind::TABLE_LEN - 2,
            OpKind::Return => OpKind::TABLE_LEN - 1,
        }
    }

    /// Every op kind, in `table_index` order.
    pub fn all() -> impl Iterator<Item = OpKind> {
        XpuOp::ALL
            .iter()
            .map(|&op| OpKind::Xpu(op))
            .chain(AffineOp::ALL.iter().map(|&op| OpKind::Affine(op)))
            .chain(ArithOp::ALL.iter().map(|&op| OpKind::Arith(op)))
            .chain([OpKind::MemRef(MemRefOp::Alloc), OpKind::Return])
    }

    /// Parse a fully-qualified op name.
    pub fn parse_name(name: &str) -> Option<OpKind> {
        if name == "func.return" || name == "return" {
            return Some(OpKind::Return);
        }
        if name == "memref.alloc" {
            return Some(OpKind::MemRef(MemRefOp::Alloc));
        }
        let (dialect, mnemonic) = name.split_once('.')?;
        match dialect {
            "xpu" => XpuOp::parse(mnemonic).map(OpKind::Xpu),
            "affine" => AffineOp::parse(mnemonic).map(OpKind::Affine),
            "arith" => ArithOp::parse(mnemonic).map(OpKind::Arith),
            _ => None,
        }
    }

    /// Does this op carry a region (a nested block)?
    pub fn has_region(&self) -> bool {
        matches!(self, OpKind::Affine(AffineOp::For))
    }

    /// Number of SSA results.
    pub fn num_results(&self) -> usize {
        match self {
            OpKind::Return
            | OpKind::Affine(AffineOp::Yield)
            | OpKind::Affine(AffineOp::Store)
            | OpKind::Affine(AffineOp::VectorStore)
            | OpKind::Affine(AffineOp::For) => 0,
            _ => 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Shape inference
// ---------------------------------------------------------------------------

fn tensor_operand<'a>(types: &'a [Type], i: usize, op: &str) -> Result<&'a TensorType> {
    types
        .get(i)
        .and_then(Type::as_tensor)
        .ok_or_else(|| anyhow!("{op}: operand {i} must be a tensor, got {:?}", types.get(i)))
}

/// Numpy-style broadcast of two shapes (dims equal, or one side is 1, or
/// ranks differ with leading-dim padding).
pub fn broadcast_shapes(a: &[i64], b: &[i64], op: &str) -> Result<Vec<i64>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0i64; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            bail!("{op}: shapes {a:?} and {b:?} are not broadcastable (dim {i}: {da} vs {db})");
        };
    }
    Ok(out)
}

fn conv_out(in_sz: i64, k: i64, stride: i64, pad: i64, op: &str) -> Result<i64> {
    let out = (in_sz + 2 * pad - k) / stride + 1;
    ensure!(out > 0, "{op}: non-positive output extent ({in_sz}+2*{pad}-{k})/{stride}+1");
    Ok(out)
}

impl XpuOp {
    /// Infer the single result type from operand types + attrs.
    ///
    /// This is both the builder's forward shape propagation and the
    /// verifier's ground truth, so every generator-produced module is
    /// checked against the same rules that created it.
    pub fn infer_result(self, operands: &[Type], attrs: &Attrs) -> Result<Type> {
        let name = format!("xpu.{}", self.mnemonic());
        let n = operands.len();
        match self {
            XpuOp::MatMul => {
                ensure!(n == 2, "{name}: expects 2 operands, got {n}");
                let a = tensor_operand(operands, 0, &name)?;
                let b = tensor_operand(operands, 1, &name)?;
                ensure!(a.rank() >= 2 && b.rank() >= 2, "{name}: operands must be rank>=2");
                let (m, k1) = (a.shape[a.rank() - 2], a.shape[a.rank() - 1]);
                let (k2, nn) = (b.shape[b.rank() - 2], b.shape[b.rank() - 1]);
                ensure!(k1 == k2, "{name}: contraction mismatch {k1} vs {k2}");
                // Batch dims come from the higher-rank side; the other side
                // must either match or be rank-2.
                let (hi, lo) = if a.rank() >= b.rank() { (a, b) } else { (b, a) };
                if lo.rank() > 2 {
                    ensure!(
                        hi.shape[..hi.rank() - 2] == lo.shape[..lo.rank() - 2],
                        "{name}: batch dims mismatch {:?} vs {:?}",
                        hi.shape,
                        lo.shape
                    );
                }
                let mut shape = hi.shape[..hi.rank() - 2].to_vec();
                shape.push(m);
                shape.push(nn);
                Ok(Type::tensor(shape, a.dtype))
            }
            XpuOp::Conv2d | XpuOp::DepthwiseConv2d => {
                ensure!(n == 2, "{name}: expects 2 operands (input, weight), got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let w = tensor_operand(operands, 1, &name)?;
                ensure!(x.rank() == 4, "{name}: input must be NCHW rank-4, got {:?}", x.shape);
                ensure!(w.rank() == 4, "{name}: weight must be rank-4, got {:?}", w.shape);
                let strides = attrs.get_int_array("strides").unwrap_or(&[1, 1]);
                let pad = attrs.get_int_array("padding").unwrap_or(&[0, 0]);
                ensure!(strides.len() == 2 && pad.len() == 2, "{name}: strides/padding must be length-2");
                let (nb, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
                let (oc, ic, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                if self == XpuOp::Conv2d {
                    ensure!(ic == c, "{name}: in-channels {ic} != input channels {c}");
                } else {
                    ensure!(oc == c && ic == 1, "{name}: depthwise weight must be [C,1,kh,kw]");
                }
                let oh = conv_out(h, kh, strides[0], pad[0], &name)?;
                let ow = conv_out(wd, kw, strides[1], pad[1], &name)?;
                Ok(Type::tensor(vec![nb, oc, oh, ow], x.dtype))
            }
            XpuOp::Conv1d => {
                ensure!(n == 2, "{name}: expects 2 operands, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let w = tensor_operand(operands, 1, &name)?;
                ensure!(x.rank() == 3 && w.rank() == 3, "{name}: (N,C,L) x (OC,IC,K)");
                let stride = attrs.get_int("stride").unwrap_or(1);
                let pad = attrs.get_int("padding").unwrap_or(0);
                ensure!(w.shape[1] == x.shape[1], "{name}: channel mismatch");
                let ol = conv_out(x.shape[2], w.shape[2], stride, pad, &name)?;
                Ok(Type::tensor(vec![x.shape[0], w.shape[0], ol], x.dtype))
            }
            XpuOp::Add | XpuOp::Sub | XpuOp::Mult | XpuOp::Div | XpuOp::Maximum | XpuOp::Minimum => {
                ensure!(n == 2, "{name}: expects 2 operands, got {n}");
                let a = tensor_operand(operands, 0, &name)?;
                let b = tensor_operand(operands, 1, &name)?;
                ensure!(a.dtype == b.dtype, "{name}: dtype mismatch {} vs {}", a.dtype, b.dtype);
                let shape = broadcast_shapes(&a.shape, &b.shape, &name)?;
                Ok(Type::tensor(shape, a.dtype))
            }
            XpuOp::Relu
            | XpuOp::Gelu
            | XpuOp::Sigmoid
            | XpuOp::Tanh
            | XpuOp::Erf
            | XpuOp::Exp
            | XpuOp::Sqrt
            | XpuOp::Rsqrt
            | XpuOp::Neg => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                Ok(Type::Tensor(x.clone()))
            }
            XpuOp::Softmax => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let axis = attrs.get_int("axis").unwrap_or(x.rank() as i64 - 1);
                ensure!(
                    (0..x.rank() as i64).contains(&axis),
                    "{name}: axis {axis} out of range for rank {}",
                    x.rank()
                );
                Ok(Type::Tensor(x.clone()))
            }
            XpuOp::BatchNorm => {
                ensure!(n == 5, "{name}: expects x, scale, bias, mean, var — got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                ensure!(x.rank() >= 2, "{name}: input rank must be >=2");
                let c = x.shape[1];
                for i in 1..5 {
                    let p = tensor_operand(operands, i, &name)?;
                    ensure!(p.shape == vec![c], "{name}: param {i} must be [{c}], got {:?}", p.shape);
                }
                Ok(Type::Tensor(x.clone()))
            }
            XpuOp::LayerNorm => {
                ensure!(n == 3, "{name}: expects x, scale, bias — got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let d = *x.shape.last().ok_or_else(|| anyhow!("{name}: rank-0 input"))?;
                for i in 1..3 {
                    let p = tensor_operand(operands, i, &name)?;
                    ensure!(p.shape == vec![d], "{name}: param {i} must be [{d}], got {:?}", p.shape);
                }
                Ok(Type::Tensor(x.clone()))
            }
            XpuOp::ReduceSum | XpuOp::ReduceMax | XpuOp::ReduceMean => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let axes: Vec<i64> = attrs
                    .get_int_array("axes")
                    .map(|a| a.to_vec())
                    .unwrap_or_else(|| (0..x.rank() as i64).collect());
                let keep = attrs.get("keepdims").and_then(|a| a.as_bool()).unwrap_or(false);
                let mut shape = Vec::new();
                for (i, &d) in x.shape.iter().enumerate() {
                    if axes.contains(&(i as i64)) {
                        if keep {
                            shape.push(1);
                        }
                    } else {
                        shape.push(d);
                    }
                }
                Ok(Type::tensor(shape, x.dtype))
            }
            XpuOp::MaxPool2d | XpuOp::AvgPool2d => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                ensure!(x.rank() == 4, "{name}: input must be NCHW");
                let k = attrs
                    .get_int_array("kernel")
                    .ok_or_else(|| anyhow!("{name}: missing kernel attr"))?;
                let strides = attrs.get_int_array("strides").unwrap_or(k);
                let pad = attrs.get_int_array("padding").unwrap_or(&[0, 0]);
                let oh = conv_out(x.shape[2], k[0], strides[0], pad[0], &name)?;
                let ow = conv_out(x.shape[3], k[1], strides[1], pad[1], &name)?;
                Ok(Type::tensor(vec![x.shape[0], x.shape[1], oh, ow], x.dtype))
            }
            XpuOp::GlobalAvgPool => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                ensure!(x.rank() == 4, "{name}: input must be NCHW");
                Ok(Type::tensor(vec![x.shape[0], x.shape[1]], x.dtype))
            }
            XpuOp::Concat => {
                ensure!(n >= 2, "{name}: expects >=2 operands, got {n}");
                let axis = attrs.get_int("axis").ok_or_else(|| anyhow!("{name}: missing axis"))?;
                let first = tensor_operand(operands, 0, &name)?;
                let ax = axis as usize;
                ensure!(ax < first.rank(), "{name}: axis {axis} out of range");
                let mut shape = first.shape.clone();
                for i in 1..n {
                    let t = tensor_operand(operands, i, &name)?;
                    ensure!(t.rank() == first.rank(), "{name}: rank mismatch");
                    for (d, (&a, &b)) in first.shape.iter().zip(&t.shape).enumerate() {
                        if d != ax {
                            ensure!(a == b, "{name}: non-axis dim {d} mismatch {a} vs {b}");
                        }
                    }
                    shape[ax] += t.shape[ax];
                }
                Ok(Type::tensor(shape, first.dtype))
            }
            XpuOp::Reshape => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let shape = attrs
                    .get_int_array("shape")
                    .ok_or_else(|| anyhow!("{name}: missing shape attr"))?
                    .to_vec();
                let new_n: i64 = shape.iter().product();
                ensure!(
                    new_n == x.num_elements(),
                    "{name}: element count mismatch {} -> {new_n}",
                    x.num_elements()
                );
                Ok(Type::tensor(shape, x.dtype))
            }
            XpuOp::Transpose => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let perm = attrs
                    .get_int_array("perm")
                    .ok_or_else(|| anyhow!("{name}: missing perm attr"))?;
                ensure!(perm.len() == x.rank(), "{name}: perm len != rank");
                let mut seen = vec![false; x.rank()];
                let mut shape = vec![0i64; x.rank()];
                for (i, &p) in perm.iter().enumerate() {
                    let p = p as usize;
                    ensure!(p < x.rank() && !seen[p], "{name}: invalid perm {perm:?}");
                    seen[p] = true;
                    shape[i] = x.shape[p];
                }
                Ok(Type::tensor(shape, x.dtype))
            }
            XpuOp::Broadcast => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let shape = attrs
                    .get_int_array("shape")
                    .ok_or_else(|| anyhow!("{name}: missing shape attr"))?
                    .to_vec();
                broadcast_shapes(&x.shape, &shape, &name)?;
                Ok(Type::tensor(shape, x.dtype))
            }
            XpuOp::Slice => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let starts = attrs
                    .get_int_array("starts")
                    .ok_or_else(|| anyhow!("{name}: missing starts"))?;
                let sizes = attrs
                    .get_int_array("sizes")
                    .ok_or_else(|| anyhow!("{name}: missing sizes"))?;
                ensure!(
                    starts.len() == x.rank() && sizes.len() == x.rank(),
                    "{name}: starts/sizes must match rank"
                );
                for i in 0..x.rank() {
                    ensure!(
                        starts[i] >= 0 && sizes[i] > 0 && starts[i] + sizes[i] <= x.shape[i],
                        "{name}: slice [{}, +{}) out of bounds for dim {} of size {}",
                        starts[i],
                        sizes[i],
                        i,
                        x.shape[i]
                    );
                }
                Ok(Type::tensor(sizes.to_vec(), x.dtype))
            }
            XpuOp::Pad => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                let low = attrs.get_int_array("low").ok_or_else(|| anyhow!("{name}: missing low"))?;
                let high = attrs.get_int_array("high").ok_or_else(|| anyhow!("{name}: missing high"))?;
                ensure!(low.len() == x.rank() && high.len() == x.rank(), "{name}: pad rank mismatch");
                let shape = x
                    .shape
                    .iter()
                    .zip(low.iter().zip(high))
                    .map(|(&d, (&l, &h))| d + l + h)
                    .collect();
                Ok(Type::tensor(shape, x.dtype))
            }
            XpuOp::Upsample => {
                ensure!(n == 1, "{name}: expects 1 operand, got {n}");
                let x = tensor_operand(operands, 0, &name)?;
                ensure!(x.rank() == 4, "{name}: input must be NCHW");
                let scale = attrs.get_int("scale").unwrap_or(2);
                Ok(Type::tensor(
                    vec![x.shape[0], x.shape[1], x.shape[2] * scale, x.shape[3] * scale],
                    x.dtype,
                ))
            }
            XpuOp::Embedding => {
                ensure!(n == 2, "{name}: expects ids, table — got {n}");
                let ids = tensor_operand(operands, 0, &name)?;
                let table = tensor_operand(operands, 1, &name)?;
                ensure!(ids.dtype == DType::I32, "{name}: ids must be i32");
                ensure!(table.rank() == 2, "{name}: table must be rank-2 [V, D]");
                let mut shape = ids.shape.clone();
                shape.push(table.shape[1]);
                Ok(Type::tensor(shape, table.dtype))
            }
            XpuOp::Const => {
                ensure!(n == 0, "{name}: expects 0 operands, got {n}");
                let shape = attrs
                    .get_int_array("shape")
                    .ok_or_else(|| anyhow!("{name}: missing shape attr"))?
                    .to_vec();
                let dtype = attrs
                    .get_str("dtype")
                    .and_then(DType::parse)
                    .ok_or_else(|| anyhow!("{name}: missing/invalid dtype attr"))?;
                Ok(Type::tensor(shape, dtype))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::attr::Attr;

    fn t(shape: &[i64]) -> Type {
        Type::tensor(shape.to_vec(), DType::F32)
    }

    #[test]
    fn opkind_name_roundtrip() {
        for op in XpuOp::ALL {
            let k = OpKind::Xpu(op);
            assert_eq!(OpKind::parse_name(&k.full_name()), Some(k));
        }
        for op in [AffineOp::For, AffineOp::Yield, AffineOp::Load, AffineOp::Store] {
            let k = OpKind::Affine(op);
            assert_eq!(OpKind::parse_name(&k.full_name()), Some(k));
        }
        assert_eq!(OpKind::parse_name("func.return"), Some(OpKind::Return));
        assert_eq!(OpKind::parse_name("bogus.op"), None);
    }

    #[test]
    fn matmul_infer() {
        let r = XpuOp::MatMul.infer_result(&[t(&[4, 8]), t(&[8, 16])], &Attrs::new()).unwrap();
        assert_eq!(r, t(&[4, 16]));
        // batched lhs, rank-2 rhs
        let r = XpuOp::MatMul
            .infer_result(&[t(&[2, 12, 64, 64]), t(&[64, 32])], &Attrs::new())
            .unwrap();
        assert_eq!(r, t(&[2, 12, 64, 32]));
        assert!(XpuOp::MatMul.infer_result(&[t(&[4, 8]), t(&[9, 16])], &Attrs::new()).is_err());
    }

    #[test]
    fn conv2d_infer() {
        let attrs = Attrs::new()
            .with("strides", Attr::IntArray(vec![2, 2]))
            .with("padding", Attr::IntArray(vec![3, 3]));
        let r = XpuOp::Conv2d
            .infer_result(&[t(&[1, 3, 224, 224]), t(&[64, 3, 7, 7])], &attrs)
            .unwrap();
        assert_eq!(r, t(&[1, 64, 112, 112]));
    }

    #[test]
    fn depthwise_infer() {
        let attrs = Attrs::new().with("padding", Attr::IntArray(vec![1, 1]));
        let r = XpuOp::DepthwiseConv2d
            .infer_result(&[t(&[1, 32, 56, 56]), t(&[32, 1, 3, 3])], &attrs)
            .unwrap();
        assert_eq!(r, t(&[1, 32, 56, 56]));
        // wrong weight layout
        assert!(XpuOp::DepthwiseConv2d
            .infer_result(&[t(&[1, 32, 56, 56]), t(&[32, 32, 3, 3])], &attrs)
            .is_err());
    }

    #[test]
    fn broadcast_binary() {
        let r = XpuOp::Add.infer_result(&[t(&[2, 16, 128]), t(&[128])], &Attrs::new()).unwrap();
        assert_eq!(r, t(&[2, 16, 128]));
        assert!(XpuOp::Add.infer_result(&[t(&[3, 4]), t(&[5, 4])], &Attrs::new()).is_err());
    }

    #[test]
    fn reduce_infer() {
        let attrs = Attrs::new().with("axes", Attr::IntArray(vec![1]));
        let r = XpuOp::ReduceSum.infer_result(&[t(&[4, 8, 16])], &attrs).unwrap();
        assert_eq!(r, t(&[4, 16]));
        let attrs = attrs.with("keepdims", Attr::Bool(true));
        let r = XpuOp::ReduceMax.infer_result(&[t(&[4, 8, 16])], &attrs).unwrap();
        assert_eq!(r, t(&[4, 1, 16]));
    }

    #[test]
    fn pool_infer() {
        let attrs = Attrs::new()
            .with("kernel", Attr::IntArray(vec![3, 3]))
            .with("strides", Attr::IntArray(vec![2, 2]))
            .with("padding", Attr::IntArray(vec![1, 1]));
        let r = XpuOp::MaxPool2d.infer_result(&[t(&[1, 64, 112, 112])], &attrs).unwrap();
        assert_eq!(r, t(&[1, 64, 56, 56]));
    }

    #[test]
    fn concat_transpose_reshape() {
        let attrs = Attrs::new().with("axis", Attr::Int(1));
        let r = XpuOp::Concat.infer_result(&[t(&[1, 64, 8, 8]), t(&[1, 32, 8, 8])], &attrs).unwrap();
        assert_eq!(r, t(&[1, 96, 8, 8]));

        let attrs = Attrs::new().with("perm", Attr::IntArray(vec![0, 2, 1]));
        let r = XpuOp::Transpose.infer_result(&[t(&[2, 3, 4])], &attrs).unwrap();
        assert_eq!(r, t(&[2, 4, 3]));

        let attrs = Attrs::new().with("shape", Attr::IntArray(vec![6, 4]));
        let r = XpuOp::Reshape.infer_result(&[t(&[2, 3, 4])], &attrs).unwrap();
        assert_eq!(r, t(&[6, 4]));
        let bad = Attrs::new().with("shape", Attr::IntArray(vec![7, 4]));
        assert!(XpuOp::Reshape.infer_result(&[t(&[2, 3, 4])], &bad).is_err());
    }

    #[test]
    fn embedding_infer() {
        let ids = Type::tensor(vec![2, 128], DType::I32);
        let table = t(&[30522, 768]);
        let r = XpuOp::Embedding.infer_result(&[ids, table], &Attrs::new()).unwrap();
        assert_eq!(r, t(&[2, 128, 768]));
    }

    #[test]
    fn const_infer() {
        let attrs = Attrs::new()
            .with("shape", Attr::IntArray(vec![64]))
            .with("dtype", Attr::Str("bf16".into()));
        let r = XpuOp::Const.infer_result(&[], &attrs).unwrap();
        assert_eq!(r, Type::tensor(vec![64], DType::BF16));
    }

    #[test]
    fn slice_pad_infer() {
        let attrs = Attrs::new()
            .with("starts", Attr::IntArray(vec![0, 2]))
            .with("sizes", Attr::IntArray(vec![2, 2]));
        let r = XpuOp::Slice.infer_result(&[t(&[2, 8])], &attrs).unwrap();
        assert_eq!(r, t(&[2, 2]));

        let attrs = Attrs::new()
            .with("low", Attr::IntArray(vec![0, 1]))
            .with("high", Attr::IntArray(vec![0, 1]));
        let r = XpuOp::Pad.infer_result(&[t(&[2, 8])], &attrs).unwrap();
        assert_eq!(r, t(&[2, 10]));
    }

    #[test]
    fn table_index_is_dense_and_matches_all_order() {
        // The id-direct encoder indexes a flat table by `table_index`;
        // the whole scheme rests on these invariants.
        let kinds: Vec<OpKind> = OpKind::all().collect();
        assert_eq!(kinds.len(), OpKind::TABLE_LEN);
        for (i, kind) in kinds.iter().enumerate() {
            assert_eq!(kind.table_index(), i, "{kind:?} out of order");
        }
        // `as usize` must agree with each sub-enum's ALL ordering.
        for (i, op) in XpuOp::ALL.iter().enumerate() {
            assert_eq!(*op as usize, i, "{op:?} declared out of ALL order");
        }
        for (i, op) in AffineOp::ALL.iter().enumerate() {
            assert_eq!(*op as usize, i, "{op:?} declared out of ALL order");
        }
        for (i, op) in ArithOp::ALL.iter().enumerate() {
            assert_eq!(*op as usize, i, "{op:?} declared out of ALL order");
        }
    }
}

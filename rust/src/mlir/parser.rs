//! Text parser for the MLIR subset produced by [`crate::mlir::printer`].
//!
//! The corpus CSVs store MLIR *text* (the paper feeds the model "Full MLIR
//! Text sequence"), so everything downstream — tokenizer, lowering, ground
//! truth — re-enters through this parser. It is a hand-rolled lexer plus
//! recursive descent over the generic-op grammar.

use super::attr::{Attr, Attrs};
use super::func::{function_from_parts, Block, Function, Module, Operation, ValueId};
use super::ops::{AffineOp, MemRefOp, OpKind};
use super::types::{DType, TensorType, Type};
use anyhow::{anyhow, bail, ensure, Context, Result};
use fxhash::FxHashMap;

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// One lexed token. Every payload is a *borrowed slice of the source
/// text* — the lexer performs zero heap allocation per token, which
/// matters because the serving hot path re-lexes every incoming query
/// (thousands of tokens per MLIR function, millions of queries per
/// compilation). `Copy` keeps the parser's `next()`/`peek()` clone-free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Tok<'a> {
    /// Bare identifier, possibly dotted: `func.func`, `affine.for`, `index`.
    Ident(&'a str),
    /// `%name` (name without the `%`).
    Value(&'a str),
    /// `@name` (name without the `@`).
    Symbol(&'a str),
    /// Integer or float literal (sign included).
    Number(&'a str),
    /// `"quoted"` string (content without quotes).
    Str(&'a str),
    /// `tensor<...>` / `memref<...>` captured whole.
    TypeLit(&'a str),
    LBrace,
    RBrace,
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Colon,
    Eq,
    Arrow,
}

pub(crate) fn lex(src: &str) -> Result<Vec<Tok<'_>>> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let n = bytes.len();
    let ident_start = |c: u8| c.is_ascii_alphabetic() || c == b'_';
    let ident_cont = |c: u8| c.is_ascii_alphanumeric() || c == b'_' || c == b'.';
    while i < n {
        let c = bytes[i];
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => i += 1,
            b'/' if i + 1 < n && bytes[i + 1] == b'/' => {
                while i < n && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'{' => {
                toks.push(Tok::LBrace);
                i += 1;
            }
            b'}' => {
                toks.push(Tok::RBrace);
                i += 1;
            }
            b'(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            b'[' => {
                toks.push(Tok::LBracket);
                i += 1;
            }
            b']' => {
                toks.push(Tok::RBracket);
                i += 1;
            }
            b',' => {
                toks.push(Tok::Comma);
                i += 1;
            }
            b':' => {
                toks.push(Tok::Colon);
                i += 1;
            }
            b'=' => {
                toks.push(Tok::Eq);
                i += 1;
            }
            b'-' if i + 1 < n && bytes[i + 1] == b'>' => {
                toks.push(Tok::Arrow);
                i += 2;
            }
            b'%' | b'@' => {
                let tag = c;
                i += 1;
                let start = i;
                while i < n && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                    i += 1;
                }
                ensure!(i > start, "empty {} name at byte {}", tag as char, start);
                let name = &src[start..i];
                toks.push(if tag == b'%' { Tok::Value(name) } else { Tok::Symbol(name) });
            }
            b'"' => {
                i += 1;
                let start = i;
                while i < n && bytes[i] != b'"' {
                    i += 1;
                }
                ensure!(i < n, "unterminated string starting at byte {start}");
                toks.push(Tok::Str(&src[start..i]));
                i += 1;
            }
            b'-' | b'0'..=b'9' => {
                let start = i;
                i += 1;
                while i < n
                    && (bytes[i].is_ascii_digit()
                        || bytes[i] == b'.'
                        || bytes[i] == b'e'
                        || bytes[i] == b'E'
                        || (bytes[i] == b'-' && matches!(bytes[i - 1], b'e' | b'E')))
                {
                    i += 1;
                }
                toks.push(Tok::Number(&src[start..i]));
            }
            c if ident_start(c) => {
                let start = i;
                while i < n && ident_cont(bytes[i]) {
                    i += 1;
                }
                let word = &src[start..i];
                // `tensor<...>` / `memref<...>` lex as one token: the dims
                // payload (`1x128xf32`) is not otherwise lexable.
                if (word == "tensor" || word == "memref") && i < n && bytes[i] == b'<' {
                    let close = src[i..]
                        .find('>')
                        .ok_or_else(|| anyhow!("unclosed {} type at byte {start}", word))?;
                    let lit = &src[start..i + close + 1];
                    i += close + 1;
                    toks.push(Tok::TypeLit(lit));
                } else {
                    toks.push(Tok::Ident(word));
                }
            }
            other => bail!("unexpected character '{}' at byte {i}", other as char),
        }
    }
    Ok(toks)
}

/// Parse `tensor<1x2xf32>` / `memref<4xbf16>` / `scalar` payloads.
pub(crate) fn parse_type_lit(lit: &str) -> Result<Type> {
    let (kind, payload) = lit
        .split_once('<')
        .ok_or_else(|| anyhow!("bad type literal {lit}"))?;
    let payload = payload.strip_suffix('>').ok_or_else(|| anyhow!("bad type literal {lit}"))?;
    let parts: Vec<&str> = payload.split('x').collect();
    let dtype = DType::parse(parts.last().copied().unwrap_or(""))
        .ok_or_else(|| anyhow!("bad dtype in {lit}"))?;
    let mut shape = Vec::with_capacity(parts.len().saturating_sub(1));
    for p in &parts[..parts.len() - 1] {
        shape.push(p.parse::<i64>().with_context(|| format!("bad dim '{p}' in {lit}"))?);
    }
    let tt = TensorType::new(shape, dtype);
    Ok(match kind {
        "tensor" => Type::Tensor(tt),
        "memref" => Type::MemRef(tt),
        _ => bail!("unknown shaped type {kind}"),
    })
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Recursive-descent parser over the borrowed token stream. `'a` is the
/// lifetime of the source text; all intermediate names stay `&'a str`
/// until a value/function actually needs an owned copy in the IR.
struct Parser<'a> {
    toks: Vec<Tok<'a>>,
    pos: usize,
}

/// Per-function symbol state while parsing. `by_name` keys borrow the
/// source text (no second `String` per value; FxHash keeps the per-lookup
/// cost down on the serving path).
struct FuncState<'a> {
    values: Vec<Type>,
    names: Vec<String>,
    by_name: FxHashMap<&'a str, ValueId>,
    num_args: usize,
}

impl<'a> FuncState<'a> {
    fn define(&mut self, name: &'a str, ty: Type) -> Result<ValueId> {
        ensure!(!self.by_name.contains_key(name), "redefinition of %{name}");
        let id = ValueId(self.values.len() as u32);
        self.values.push(ty);
        self.names.push(name.to_string());
        self.by_name.insert(name, id);
        Ok(id)
    }

    fn lookup(&self, name: &str) -> Result<ValueId> {
        self.by_name
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("use of undefined value %{name}"))
    }
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&Tok<'a>> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Tok<'a>> {
        let t = self.toks.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, t: Tok<'a>) -> Result<()> {
        let got = self.next()?;
        ensure!(got == t, "expected {t:?}, got {got:?} at token {}", self.pos - 1);
        Ok(())
    }

    fn eat(&mut self, t: Tok<'a>) -> bool {
        if self.peek() == Some(&t) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_ident(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s == kw => Ok(()),
            got => bail!("expected '{kw}', got {got:?}"),
        }
    }

    fn value_name(&mut self) -> Result<&'a str> {
        match self.next()? {
            Tok::Value(s) => Ok(s),
            got => bail!("expected %value, got {got:?}"),
        }
    }

    fn int(&mut self) -> Result<i64> {
        match self.next()? {
            Tok::Number(s) => s.parse::<i64>().with_context(|| format!("bad integer '{s}'")),
            got => bail!("expected integer, got {got:?}"),
        }
    }

    fn parse_type(&mut self) -> Result<Type> {
        match self.next()? {
            Tok::TypeLit(lit) => parse_type_lit(lit),
            Tok::Ident("index") => Ok(Type::Index),
            Tok::Ident(s) => DType::parse(s)
                .map(Type::Scalar)
                .ok_or_else(|| anyhow!("unknown type '{s}'")),
            got => bail!("expected a type, got {got:?}"),
        }
    }

    fn parse_attr_value(&mut self) -> Result<Attr> {
        match self.next()? {
            Tok::Number(s) => {
                if s.contains('.') || s.contains('e') || s.contains('E') {
                    Ok(Attr::Float(s.parse::<f64>().with_context(|| format!("bad float '{s}'"))?))
                } else {
                    Ok(Attr::Int(s.parse::<i64>().with_context(|| format!("bad int '{s}'"))?))
                }
            }
            Tok::Str(s) => Ok(Attr::Str(s.to_string())),
            Tok::Ident("true") => Ok(Attr::Bool(true)),
            Tok::Ident("false") => Ok(Attr::Bool(false)),
            Tok::LBracket => {
                let mut v = Vec::new();
                if !self.eat(Tok::RBracket) {
                    loop {
                        v.push(self.int()?);
                        if !self.eat(Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(Tok::RBracket)?;
                }
                Ok(Attr::IntArray(v))
            }
            got => bail!("expected attribute value, got {got:?}"),
        }
    }

    /// Parse an optional `{k = v, ...}` dictionary.
    fn parse_attrs(&mut self) -> Result<Attrs> {
        let mut attrs = Attrs::new();
        if !self.eat(Tok::LBrace) {
            return Ok(attrs);
        }
        if self.eat(Tok::RBrace) {
            return Ok(attrs);
        }
        loop {
            let key = match self.next()? {
                Tok::Ident(s) => s,
                got => bail!("expected attribute key, got {got:?}"),
            };
            self.expect(Tok::Eq)?;
            let value = self.parse_attr_value()?;
            attrs.set(key, value);
            if !self.eat(Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RBrace)?;
        Ok(attrs)
    }

    fn parse_index_list(&mut self, st: &FuncState<'a>) -> Result<Vec<ValueId>> {
        self.expect(Tok::LBracket)?;
        let mut idx = Vec::new();
        if !self.eat(Tok::RBracket) {
            loop {
                idx.push(st.lookup(self.value_name()?)?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RBracket)?;
        }
        Ok(idx)
    }

    /// Parse the ops of one block until the closing `}` (consumed).
    fn parse_block_body(&mut self, st: &mut FuncState<'a>, block: &mut Block) -> Result<()> {
        loop {
            if self.eat(Tok::RBrace) {
                return Ok(());
            }
            match self.peek().copied() {
                Some(Tok::Ident("return")) => {
                    self.next()?;
                    let mut operands = Vec::new();
                    if matches!(self.peek(), Some(Tok::Value(_))) {
                        loop {
                            operands.push(st.lookup(self.value_name()?)?);
                            if !self.eat(Tok::Comma) {
                                break;
                            }
                        }
                        self.expect(Tok::Colon)?;
                        for i in 0..operands.len() {
                            if i > 0 {
                                self.expect(Tok::Comma)?;
                            }
                            self.parse_type()?;
                        }
                    }
                    block.ops.push(Operation {
                        kind: OpKind::Return,
                        operands,
                        results: vec![],
                        attrs: Attrs::new(),
                        region: None,
                    });
                }
                Some(Tok::Ident("affine.for")) => {
                    self.next()?;
                    let iv_name = self.value_name()?;
                    self.expect(Tok::Eq)?;
                    let lb = self.int()?;
                    self.expect_ident("to")?;
                    let ub = self.int()?;
                    let step = if matches!(self.peek(), Some(Tok::Ident(s)) if *s == "step") {
                        self.next()?;
                        self.int()?
                    } else {
                        1
                    };
                    self.expect(Tok::LBrace)?;
                    let iv = st.define(iv_name, Type::Index)?;
                    let mut body = Block { args: vec![iv], ops: Vec::new() };
                    self.parse_block_body(st, &mut body)?;
                    let attrs = Attrs::new()
                        .with("lb", Attr::Int(lb))
                        .with("ub", Attr::Int(ub))
                        .with("step", Attr::Int(step));
                    block.ops.push(Operation {
                        kind: OpKind::Affine(AffineOp::For),
                        operands: vec![],
                        results: vec![],
                        attrs,
                        region: Some(body),
                    });
                }
                Some(Tok::Ident("affine.yield")) => {
                    self.next()?;
                    block.ops.push(Operation {
                        kind: OpKind::Affine(AffineOp::Yield),
                        operands: vec![],
                        results: vec![],
                        attrs: Attrs::new(),
                        region: None,
                    });
                }
                Some(Tok::Ident(kw @ ("affine.store" | "affine.vector_store"))) => {
                    self.next()?;
                    let value = st.lookup(self.value_name()?)?;
                    self.expect(Tok::Comma)?;
                    let memref = st.lookup(self.value_name()?)?;
                    let indices = self.parse_index_list(st)?;
                    let attrs = self.parse_attrs()?;
                    self.expect(Tok::Colon)?;
                    self.parse_type()?;
                    let mut operands = vec![value, memref];
                    operands.extend(indices);
                    let op = if kw == "affine.store" {
                        AffineOp::Store
                    } else {
                        AffineOp::VectorStore
                    };
                    block.ops.push(Operation {
                        kind: OpKind::Affine(op),
                        operands,
                        results: vec![],
                        attrs,
                        region: None,
                    });
                }
                Some(Tok::Value(_)) => {
                    // %r = <something>
                    let result_name = self.value_name()?;
                    self.expect(Tok::Eq)?;
                    match self.next()? {
                        Tok::Ident(kw @ ("affine.load" | "affine.vector_load")) => {
                            let memref = st.lookup(self.value_name()?)?;
                            let indices = self.parse_index_list(st)?;
                            let attrs = self.parse_attrs()?;
                            self.expect(Tok::Colon)?;
                            self.parse_type()?;
                            let dtype = st.values[memref.0 as usize]
                                .as_memref()
                                .ok_or_else(|| anyhow!("{kw}: %{result_name} base not a memref"))?
                                .dtype;
                            let result = st.define(result_name, Type::Scalar(dtype))?;
                            let mut operands = vec![memref];
                            operands.extend(indices);
                            let op = if kw == "affine.load" {
                                AffineOp::Load
                            } else {
                                AffineOp::VectorLoad
                            };
                            block.ops.push(Operation {
                                kind: OpKind::Affine(op),
                                operands,
                                results: vec![result],
                                attrs,
                                region: None,
                            });
                        }
                        Tok::Ident("memref.alloc") => {
                            self.expect(Tok::LParen)?;
                            self.expect(Tok::RParen)?;
                            self.expect(Tok::Colon)?;
                            let ty = self.parse_type()?;
                            ensure!(ty.as_memref().is_some(), "memref.alloc must yield a memref");
                            let result = st.define(result_name, ty)?;
                            block.ops.push(Operation {
                                kind: OpKind::MemRef(MemRefOp::Alloc),
                                operands: vec![],
                                results: vec![result],
                                attrs: Attrs::new(),
                                region: None,
                            });
                        }
                        Tok::Str(opname) => {
                            // generic: "xpu.conv2d"(%a, %b) {attrs} : (..) -> t
                            let kind = OpKind::parse_name(opname)
                                .ok_or_else(|| anyhow!("unknown op \"{opname}\""))?;
                            self.expect(Tok::LParen)?;
                            let mut operands = Vec::new();
                            if !self.eat(Tok::RParen) {
                                loop {
                                    operands.push(st.lookup(self.value_name()?)?);
                                    if !self.eat(Tok::Comma) {
                                        break;
                                    }
                                }
                                self.expect(Tok::RParen)?;
                            }
                            let attrs = self.parse_attrs()?;
                            self.expect(Tok::Colon)?;
                            self.expect(Tok::LParen)?;
                            for i in 0..operands.len() {
                                if i > 0 {
                                    self.expect(Tok::Comma)?;
                                }
                                self.parse_type()?;
                            }
                            self.expect(Tok::RParen)?;
                            self.expect(Tok::Arrow)?;
                            let result_ty = self.parse_type()?;
                            let result = st.define(result_name, result_ty)?;
                            block.ops.push(Operation {
                                kind,
                                operands,
                                results: vec![result],
                                attrs,
                                region: None,
                            });
                        }
                        got => bail!("unexpected token after '%{result_name} =': {got:?}"),
                    }
                }
                got => bail!("unexpected token in block: {got:?}"),
            }
        }
    }

    fn parse_function(&mut self) -> Result<Function> {
        self.expect_ident("func.func")?;
        let name = match self.next()? {
            Tok::Symbol(s) => s,
            got => bail!("expected @name, got {got:?}"),
        };
        let mut st = FuncState {
            values: Vec::new(),
            names: Vec::new(),
            by_name: FxHashMap::default(),
            num_args: 0,
        };
        self.expect(Tok::LParen)?;
        if !self.eat(Tok::RParen) {
            loop {
                let arg_name = self.value_name()?;
                self.expect(Tok::Colon)?;
                let ty = self.parse_type()?;
                st.define(arg_name, ty)?;
                st.num_args += 1;
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RParen)?;
        }
        if self.eat(Tok::Arrow) {
            if self.eat(Tok::LParen) {
                loop {
                    self.parse_type()?;
                    if !self.eat(Tok::Comma) {
                        break;
                    }
                }
                self.expect(Tok::RParen)?;
            } else {
                self.parse_type()?;
            }
        }
        self.expect(Tok::LBrace)?;
        let mut body = Block::default();
        self.parse_block_body(&mut st, &mut body)?;
        let ret = match body.ops.last() {
            Some(op) if op.kind == OpKind::Return => op.operands.clone(),
            _ => bail!("function @{name} does not end in return"),
        };
        function_from_parts(name.to_string(), st.values, st.names, st.num_args, ret, body)
    }
}

/// Parse a single standalone function.
pub fn parse_function(src: &str) -> Result<Function> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    let f = p.parse_function()?;
    ensure!(p.peek().is_none(), "trailing input after function");
    Ok(f)
}

/// Parse a `module @name { ... }` wrapper (or a bare function).
pub fn parse_module(src: &str) -> Result<Module> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    if matches!(p.peek(), Some(Tok::Ident(s)) if *s == "module") {
        p.next()?;
        let name = match p.next()? {
            Tok::Symbol(s) => s,
            got => bail!("expected @name after 'module', got {got:?}"),
        };
        p.expect(Tok::LBrace)?;
        let mut m = Module::new(name);
        while !p.eat(Tok::RBrace) {
            m.functions.push(p.parse_function()?);
        }
        ensure!(p.peek().is_none(), "trailing input after module");
        Ok(m)
    } else {
        let f = p.parse_function()?;
        ensure!(p.peek().is_none(), "trailing input after function");
        let mut m = Module::new("anon");
        m.functions.push(f);
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::attr::{Attr, Attrs};
    use crate::mlir::func::FuncBuilder;
    use crate::mlir::ops::{ArithOp, XpuOp};
    use crate::mlir::printer::{print_function, print_module};

    #[test]
    fn roundtrip_simple() {
        let src = "\
func.func @f(%arg0: tensor<4x8xf32>, %arg1: tensor<8x16xf32>) -> tensor<4x16xf32> {
  %0 = \"xpu.matmul\"(%arg0, %arg1) : (tensor<4x8xf32>, tensor<8x16xf32>) -> tensor<4x16xf32>
  %1 = \"xpu.relu\"(%0) : (tensor<4x16xf32>) -> tensor<4x16xf32>
  return %1 : tensor<4x16xf32>
}
";
        let f = parse_function(src).unwrap();
        assert_eq!(f.name, "f");
        assert_eq!(f.num_ops(), 2);
        assert_eq!(print_function(&f), src);
    }

    #[test]
    fn roundtrip_built_function() {
        let mut b = FuncBuilder::new("rt");
        let x = b.arg(Type::tensor(vec![1, 3, 32, 32], DType::F32));
        let w = b.arg(Type::tensor(vec![16, 3, 3, 3], DType::F32));
        let c = b
            .xpu(
                XpuOp::Conv2d,
                &[x, w],
                Attrs::new()
                    .with("strides", Attr::IntArray(vec![1, 1]))
                    .with("padding", Attr::IntArray(vec![1, 1])),
            )
            .unwrap();
        let s = b.xpu(XpuOp::Sigmoid, &[c], Attrs::new()).unwrap();
        let f = b.ret(&[s]).unwrap();
        let text = print_function(&f);
        let f2 = parse_function(&text).unwrap();
        assert_eq!(print_function(&f2), text);
    }

    #[test]
    fn roundtrip_loops_and_arith() {
        let mut b = FuncBuilder::new("loops");
        let m = b.alloc(vec![16, 16], DType::F32);
        let i = b.begin_for(0, 16, 1);
        let j = b.begin_for(0, 16, 4);
        let v = b.load(m, &[i, j]).unwrap();
        let c = b
            .arith(ArithOp::Constant, &[], Attrs::new().with("value", Attr::Float(1.5)))
            .unwrap();
        let a = b.arith(ArithOp::AddF, &[v, c], Attrs::new()).unwrap();
        b.store(a, m, &[i, j]).unwrap();
        b.end_for().unwrap();
        b.end_for().unwrap();
        let f = b.ret(&[]).unwrap();
        let text = print_function(&f);
        let f2 = parse_function(&text).unwrap();
        assert_eq!(print_function(&f2), text);
        assert_eq!(f2.max_loop_depth(), 2);
    }

    #[test]
    fn module_roundtrip() {
        let mut b = FuncBuilder::new("g");
        let x = b.arg(Type::tensor(vec![4], DType::BF16));
        let y = b.xpu(XpuOp::Exp, &[x], Attrs::new()).unwrap();
        let f = b.ret(&[y]).unwrap();
        let mut m = Module::new("corpus_file");
        m.functions.push(f);
        let text = print_module(&m);
        let m2 = parse_module(&text).unwrap();
        assert_eq!(m2.name, "corpus_file");
        assert_eq!(print_module(&m2), text);
    }

    #[test]
    fn parse_errors() {
        assert!(parse_function("func.func @f() {").is_err()); // truncated
        assert!(parse_function(
            "func.func @f() {\n  %0 = \"xpu.bogus\"() : () -> tensor<1xf32>\n  return\n}"
        )
        .is_err()); // unknown op
        assert!(parse_function(
            "func.func @f() {\n  return %9 : tensor<1xf32>\n}"
        )
        .is_err()); // undefined value
    }

    #[test]
    fn parse_multiline_attrs_and_bools() {
        let src = "\
func.func @f(%arg0: tensor<4x8xf32>) -> tensor<4xf32> {
  %0 = \"xpu.reduce_mean\"(%arg0) {axes = [1], keepdims = false} : (tensor<4x8xf32>) -> tensor<4xf32>
  return %0 : tensor<4xf32>
}
";
        let f = parse_function(src).unwrap();
        assert_eq!(print_function(&f), src);
    }
}

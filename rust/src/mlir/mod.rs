//! From-scratch MLIR core for the `xpu` (high-level NN operator) and
//! `affine`/`arith`/`memref` (loop-level) dialect subset the paper's cost
//! model consumes.
//!
//! - [`types`] — dtypes, tensor/memref/scalar/index types
//! - [`attr`] — op attribute dictionaries
//! - [`ops`] — opcode registry + shape inference
//! - [`func`] — SSA values, operations, blocks, functions, builder
//! - [`printer`] / [`parser`] — deterministic text round-trip
//! - [`verifier`] — re-checks parsed IR against the inference rules

pub mod attr;
pub mod func;
pub mod ops;
pub mod parser;
pub mod printer;
pub mod types;
pub mod verifier;

pub use attr::{Attr, Attrs};
pub use func::{Block, FuncBuilder, Function, Module, Operation, ValueId};
pub use ops::{AffineOp, ArithOp, MemRefOp, OpKind, XpuOp};
pub use parser::{parse_function, parse_module};
pub use printer::{print_function, print_module};
pub use types::{DType, TensorType, Type};
pub use verifier::{verify_function, verify_module};

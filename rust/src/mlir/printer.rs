//! Textual form emission. The printed text is what the corpus CSVs store
//! (the paper's "Full MLIR Text sequence" column), so printing must be
//! deterministic and must round-trip through [`crate::mlir::parser`].

use super::func::{Block, Function, Module, Operation, ValueId};
use super::ops::{AffineOp, OpKind};
use std::fmt::Write as _;

/// Print a module in MLIR generic-ish syntax.
pub fn print_module(module: &Module) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "module @{} {{", module.name);
    for f in &module.functions {
        print_function_into(f, 1, &mut out);
    }
    out.push_str("}\n");
    out
}

/// Print a single function (top-level, no module wrapper).
pub fn print_function(f: &Function) -> String {
    let mut out = String::new();
    print_function_into(f, 0, &mut out);
    out
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn print_function_into(f: &Function, depth: usize, out: &mut String) {
    indent(out, depth);
    let _ = write!(out, "func.func @{}(", f.name);
    for (i, id) in f.arg_ids().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "%{}: {}", f.value_name(id), f.value_type(id));
    }
    out.push(')');
    let rets = f.ret_types();
    if !rets.is_empty() {
        out.push_str(" -> ");
        if rets.len() > 1 {
            out.push('(');
        }
        for (i, t) in rets.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{t}");
        }
        if rets.len() > 1 {
            out.push(')');
        }
    }
    out.push_str(" {\n");
    print_block(f, &f.body, depth + 1, out);
    indent(out, depth);
    out.push_str("}\n");
}

fn val(f: &Function, id: ValueId) -> String {
    format!("%{}", f.value_name(id))
}

fn print_block(f: &Function, block: &Block, depth: usize, out: &mut String) {
    for op in &block.ops {
        print_op(f, op, depth, out);
    }
}

fn print_op(f: &Function, op: &Operation, depth: usize, out: &mut String) {
    indent(out, depth);
    match op.kind {
        OpKind::Return => {
            out.push_str("return");
            if !op.operands.is_empty() {
                out.push(' ');
                for (i, &o) in op.operands.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&val(f, o));
                }
                out.push_str(" : ");
                for (i, &o) in op.operands.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    let _ = write!(out, "{}", f.value_type(o));
                }
            }
            out.push('\n');
        }
        OpKind::Affine(AffineOp::For) => {
            let region = op.region.as_ref().expect("affine.for has a region");
            let iv = region.args[0];
            let lb = op.attrs.get_int("lb").unwrap_or(0);
            let ub = op.attrs.get_int("ub").unwrap_or(0);
            let step = op.attrs.get_int("step").unwrap_or(1);
            let _ = write!(out, "affine.for {} = {lb} to {ub}", val(f, iv));
            if step != 1 {
                let _ = write!(out, " step {step}");
            }
            out.push_str(" {\n");
            print_block(f, region, depth + 1, out);
            indent(out, depth);
            out.push_str("}\n");
        }
        OpKind::Affine(AffineOp::Yield) => {
            out.push_str("affine.yield\n");
        }
        OpKind::Affine(AffineOp::Load) | OpKind::Affine(AffineOp::VectorLoad) => {
            let mnemonic = if op.kind == OpKind::Affine(AffineOp::Load) {
                "load"
            } else {
                "vector_load"
            };
            let memref = op.operands[0];
            let _ = write!(out, "{} = affine.{mnemonic} {}[", val(f, op.results[0]), val(f, memref));
            for (i, &ix) in op.operands[1..].iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&val(f, ix));
            }
            out.push(']');
            if !op.attrs.is_empty() {
                let _ = write!(out, " {}", op.attrs);
            }
            let _ = writeln!(out, " : {}", f.value_type(memref));
        }
        OpKind::Affine(AffineOp::Store) | OpKind::Affine(AffineOp::VectorStore) => {
            let mnemonic = if op.kind == OpKind::Affine(AffineOp::Store) {
                "store"
            } else {
                "vector_store"
            };
            let value = op.operands[0];
            let memref = op.operands[1];
            let _ = write!(out, "affine.{mnemonic} {}, {}[", val(f, value), val(f, memref));
            for (i, &ix) in op.operands[2..].iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&val(f, ix));
            }
            out.push(']');
            if !op.attrs.is_empty() {
                let _ = write!(out, " {}", op.attrs);
            }
            let _ = writeln!(out, " : {}", f.value_type(memref));
        }
        OpKind::MemRef(_) => {
            let _ = writeln!(
                out,
                "{} = memref.alloc() : {}",
                val(f, op.results[0]),
                f.value_type(op.results[0])
            );
        }
        OpKind::Xpu(_) | OpKind::Arith(_) => {
            // Generic form: %r = "dialect.op"(%a, %b) {attrs} : (t, t) -> t
            let _ = write!(out, "{} = \"{}\"(", val(f, op.results[0]), op.kind.full_name());
            for (i, &o) in op.operands.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&val(f, o));
            }
            out.push(')');
            if !op.attrs.is_empty() {
                let _ = write!(out, " {}", op.attrs);
            }
            out.push_str(" : (");
            for (i, &o) in op.operands.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                let _ = write!(out, "{}", f.value_type(o));
            }
            let _ = writeln!(out, ") -> {}", f.value_type(op.results[0]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::attr::{Attr, Attrs};
    use crate::mlir::func::FuncBuilder;
    use crate::mlir::ops::XpuOp;
    use crate::mlir::types::{DType, Type};

    #[test]
    fn print_matches_paper_style() {
        let mut b = FuncBuilder::new("subgraph");
        let x = b.arg(Type::tensor(vec![1, 64, 56, 56], DType::F32));
        let w = b.arg(Type::tensor(vec![64, 64, 3, 3], DType::F32));
        let c = b
            .xpu(
                XpuOp::Conv2d,
                &[x, w],
                Attrs::new()
                    .with("strides", Attr::IntArray(vec![1, 1]))
                    .with("padding", Attr::IntArray(vec![1, 1])),
            )
            .unwrap();
        let r = b.xpu(XpuOp::Relu, &[c], Attrs::new()).unwrap();
        let f = b.ret(&[r]).unwrap();
        let text = print_function(&f);
        assert!(text.contains("func.func @subgraph(%arg0: tensor<1x64x56x56xf32>"));
        assert!(text.contains(
            "%0 = \"xpu.conv2d\"(%arg0, %arg1) {strides = [1, 1], padding = [1, 1]} : \
             (tensor<1x64x56x56xf32>, tensor<64x64x3x3xf32>) -> tensor<1x64x56x56xf32>"
        ));
        assert!(text.contains("return %1 : tensor<1x64x56x56xf32>"));
    }

    #[test]
    fn print_loop_nest() {
        let mut b = FuncBuilder::new("loops");
        let m = b.alloc(vec![8, 8], DType::F32);
        let i = b.begin_for(0, 8, 2);
        let v = b.load(m, &[i, i]).unwrap();
        b.store(v, m, &[i, i]).unwrap();
        b.end_for().unwrap();
        let f = b.ret(&[]).unwrap();
        let text = print_function(&f);
        assert!(text.contains("affine.for %1 = 0 to 8 step 2 {"));
        assert!(text.contains("%2 = affine.load %0[%1, %1] : memref<8x8xf32>"));
        assert!(text.contains("affine.store %2, %0[%1, %1] : memref<8x8xf32>"));
        assert!(text.contains("affine.yield"));
    }
}

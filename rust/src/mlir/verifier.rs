//! IR verifier: re-derives every op's result type from its operands via
//! the shared inference rules and checks SSA dominance, so a parsed module
//! is guaranteed to be as well-formed as a builder-produced one.

use super::func::{Block, Function, Module, ValueId};
use super::ops::{AffineOp, ArithOp, OpKind};
use super::types::Type;
use anyhow::{bail, ensure, Result};

/// Verify a whole module.
pub fn verify_module(module: &Module) -> Result<()> {
    for f in &module.functions {
        verify_function(f)
            .map_err(|e| e.context(format!("in function @{}", f.name)))?;
    }
    Ok(())
}

/// Verify one function: dominance, operand/result sanity, type inference
/// agreement, and terminator placement.
pub fn verify_function(f: &Function) -> Result<()> {
    let mut defined = vec![false; f.num_values()];
    for id in f.arg_ids() {
        defined[id.0 as usize] = true;
    }
    verify_block(f, &f.body, &mut defined, 0)?;
    // Return values must be defined at top level.
    for &r in &f.ret {
        ensure!(
            defined[r.0 as usize],
            "return value %{} is never defined",
            f.value_name(r)
        );
    }
    Ok(())
}

fn verify_block(f: &Function, block: &Block, defined: &mut [bool], depth: usize) -> Result<()> {
    for &arg in &block.args {
        ensure!(
            !defined[arg.0 as usize],
            "block arg %{} already defined",
            f.value_name(arg)
        );
        defined[arg.0 as usize] = true;
    }
    let n = block.ops.len();
    for (i, op) in block.ops.iter().enumerate() {
        // Dominance: every operand must be defined before use.
        for &o in &op.operands {
            ensure!(
                defined[o.0 as usize],
                "{}: operand %{} used before definition",
                op.kind.full_name(),
                f.value_name(o)
            );
        }
        // Results defined exactly once.
        ensure!(
            op.results.len() == op.kind.num_results(),
            "{}: expected {} results, has {}",
            op.kind.full_name(),
            op.kind.num_results(),
            op.results.len()
        );
        for &r in &op.results {
            ensure!(
                !defined[r.0 as usize],
                "%{} defined more than once",
                f.value_name(r)
            );
            defined[r.0 as usize] = true;
        }
        // Region discipline.
        ensure!(
            op.region.is_some() == op.kind.has_region(),
            "{}: region mismatch",
            op.kind.full_name()
        );
        // Terminators.
        match op.kind {
            OpKind::Return => {
                ensure!(depth == 0, "func.return inside a region");
                ensure!(i == n - 1, "func.return must be the last op of the body");
            }
            OpKind::Affine(AffineOp::Yield) => {
                ensure!(depth > 0, "affine.yield outside a loop body");
                ensure!(i == n - 1, "affine.yield must terminate its block");
            }
            _ => {}
        }
        verify_op_types(f, op)?;
        if let Some(region) = &op.region {
            ensure!(region.args.len() == 1, "affine.for region must have one iv arg");
            ensure!(
                f.value_type(region.args[0]) == &Type::Index,
                "affine.for iv must be index-typed"
            );
            ensure!(
                matches!(
                    region.ops.last().map(|o| o.kind),
                    Some(OpKind::Affine(AffineOp::Yield))
                ),
                "affine.for body must end in affine.yield"
            );
            let lb = op.attrs.get_int("lb").unwrap_or(0);
            let ub = op.attrs.get_int("ub").unwrap_or(0);
            let step = op.attrs.get_int("step").unwrap_or(1);
            ensure!(step > 0, "affine.for step must be positive, got {step}");
            ensure!(ub >= lb, "affine.for bounds inverted: {lb}..{ub}");
            verify_block(f, region, defined, depth + 1)?;
        }
    }
    // Top-level body must end with return.
    if depth == 0 {
        ensure!(
            matches!(block.ops.last().map(|o| o.kind), Some(OpKind::Return)),
            "function body must end in func.return"
        );
    }
    Ok(())
}

fn verify_op_types(f: &Function, op: &super::func::Operation) -> Result<()> {
    let operand_types: Vec<Type> =
        op.operands.iter().map(|&o| f.value_type(o).clone()).collect();
    match op.kind {
        OpKind::Xpu(x) => {
            let inferred = x.infer_result(&operand_types, &op.attrs)?;
            let declared = f.value_type(op.results[0]);
            ensure!(
                &inferred == declared,
                "xpu.{}: declared result type {declared} != inferred {inferred}",
                x.mnemonic()
            );
        }
        OpKind::Arith(a) => {
            if a == ArithOp::Constant {
                ensure!(op.operands.is_empty(), "arith.constant takes no operands");
            } else {
                ensure!(!op.operands.is_empty(), "arith.{} needs operands", a.mnemonic());
                for t in &operand_types {
                    ensure!(
                        matches!(t, Type::Scalar(_)),
                        "arith.{}: non-scalar operand {t}",
                        a.mnemonic()
                    );
                }
            }
            ensure!(
                matches!(f.value_type(op.results[0]), Type::Scalar(_)),
                "arith.{}: result must be scalar",
                a.mnemonic()
            );
        }
        OpKind::Affine(AffineOp::Load) | OpKind::Affine(AffineOp::VectorLoad) => {
            let base = operand_types
                .first()
                .and_then(Type::as_memref)
                .map(|t| t.rank());
            let Some(rank) = base else { bail!("affine.load base must be a memref") };
            ensure!(
                op.operands.len() == 1 + rank,
                "affine.load: expected {rank} indices"
            );
            for t in &operand_types[1..] {
                ensure!(t == &Type::Index, "affine.load index must be index-typed");
            }
        }
        OpKind::Affine(AffineOp::Store) | OpKind::Affine(AffineOp::VectorStore) => {
            ensure!(op.operands.len() >= 2, "affine.store needs value + memref");
            let Some(mr) = operand_types[1].as_memref() else {
                bail!("affine.store target must be a memref")
            };
            ensure!(
                op.operands.len() == 2 + mr.rank(),
                "affine.store: expected {} indices",
                mr.rank()
            );
        }
        OpKind::MemRef(_) => {
            ensure!(
                f.value_type(op.results[0]).as_memref().is_some(),
                "memref.alloc result must be a memref"
            );
        }
        _ => {}
    }
    Ok(())
}

/// Convenience: ids of all values live in `f` (for tests).
pub fn all_value_ids(f: &Function) -> Vec<ValueId> {
    (0..f.num_values() as u32).map(ValueId).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::attr::{Attr, Attrs};
    use crate::mlir::func::FuncBuilder;
    use crate::mlir::ops::XpuOp;
    use crate::mlir::parser::parse_function;
    use crate::mlir::types::DType;

    #[test]
    fn builder_output_verifies() {
        let mut b = FuncBuilder::new("ok");
        let x = b.arg(Type::tensor(vec![2, 4], DType::F32));
        let y = b.xpu(XpuOp::Relu, &[x], Attrs::new()).unwrap();
        let f = b.ret(&[y]).unwrap();
        verify_function(&f).unwrap();
    }

    #[test]
    fn catches_declared_type_lie() {
        // Parsed text declares a wrong result shape for matmul.
        let src = "\
func.func @bad(%arg0: tensor<4x8xf32>, %arg1: tensor<8x16xf32>) -> tensor<4x99xf32> {
  %0 = \"xpu.matmul\"(%arg0, %arg1) : (tensor<4x8xf32>, tensor<8x16xf32>) -> tensor<4x99xf32>
  return %0 : tensor<4x99xf32>
}
";
        let f = parse_function(src).unwrap();
        let err = verify_function(&f).unwrap_err();
        assert!(err.to_string().contains("inferred"));
    }

    #[test]
    fn loop_function_verifies() {
        let mut b = FuncBuilder::new("loop");
        let m = b.alloc(vec![4, 4], DType::F32);
        let i = b.begin_for(0, 4, 1);
        let v = b.load(m, &[i, i]).unwrap();
        b.store(v, m, &[i, i]).unwrap();
        b.end_for().unwrap();
        let f = b.ret(&[]).unwrap();
        verify_function(&f).unwrap();
    }

    #[test]
    fn catches_bad_attr_in_parsed_op() {
        let src = "\
func.func @bad(%arg0: tensor<2x3x4xf32>) -> tensor<6x4xf32> {
  %0 = \"xpu.reshape\"(%arg0) {shape = [5, 4]} : (tensor<2x3x4xf32>) -> tensor<6x4xf32>
  return %0 : tensor<6x4xf32>
}
";
        let f = parse_function(src).unwrap();
        assert!(verify_function(&f).is_err());
    }

    #[test]
    fn value_id_enumeration() {
        let mut b = FuncBuilder::new("ids");
        let x = b.arg(Type::tensor(vec![2], DType::F32));
        let y = b.xpu(XpuOp::Neg, &[x], Attrs::new()).unwrap();
        let f = b.ret(&[y]).unwrap();
        assert_eq!(all_value_ids(&f).len(), 2);
    }

    #[test]
    fn const_op_verifies() {
        let mut b = FuncBuilder::new("c");
        let c = b
            .xpu(
                XpuOp::Const,
                &[],
                Attrs::new()
                    .with("shape", Attr::IntArray(vec![8]))
                    .with("dtype", Attr::Str("f32".into())),
            )
            .unwrap();
        let f = b.ret(&[c]).unwrap();
        verify_function(&f).unwrap();
    }
}

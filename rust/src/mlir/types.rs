//! Type system for the `xpu` / `affine` MLIR subset.
//!
//! Only ranked tensors with static shapes appear in the corpora this
//! library generates — the paper's tokenizer treats a tensor shape as a
//! single token (e.g. `tensor<1x128x768xf32>`), which requires shapes to
//! be fully static.

use std::fmt;

/// Element datatype of a tensor. Mirrors the dtypes the paper's `xpu`
/// dialect operates on (AI-accelerator-centric).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
    I8,
    I1,
}

impl DType {
    /// Size of one element in bytes (i1 is stored as one byte).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
            DType::I8 | DType::I1 => 1,
        }
    }

    /// MLIR spelling, e.g. `f32`.
    pub fn mlir_name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I32 => "i32",
            DType::I8 => "i8",
            DType::I1 => "i1",
        }
    }

    /// Parse an MLIR dtype spelling.
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "bf16" => DType::BF16,
            "f16" => DType::F16,
            "i32" => DType::I32,
            "i8" => DType::I8,
            "i1" => DType::I1,
            _ => return None,
        })
    }

    /// True for floating-point types.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::BF16 | DType::F16)
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mlir_name())
    }
}

/// A ranked, statically-shaped tensor type: `tensor<2x3x4xf32>`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub shape: Vec<i64>,
    pub dtype: DType,
}

impl TensorType {
    pub fn new(shape: Vec<i64>, dtype: DType) -> Self {
        debug_assert!(shape.iter().all(|&d| d >= 0), "negative dim in {shape:?}");
        TensorType { shape, dtype }
    }

    /// Rank (number of dimensions). A scalar tensor has rank 0.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total number of elements.
    pub fn num_elements(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Total byte footprint.
    pub fn size_bytes(&self) -> usize {
        self.num_elements() as usize * self.dtype.size_bytes()
    }

    /// The paper tokenizes a whole shape as a single token; this is that
    /// token's spelling, e.g. `1x128x768xf32` (rank-0 → `xf32` degenerate
    /// form avoided by spelling `scalar_f32`).
    pub fn shape_token(&self) -> String {
        let mut s = String::new();
        self.write_shape_token(&mut s);
        s
    }

    /// Append the shape token to `out` without intermediate allocation
    /// (the serving tokenizer reuses one scratch `String` per query).
    pub fn write_shape_token(&self, out: &mut String) {
        use std::fmt::Write as _;
        if self.shape.is_empty() {
            let _ = write!(out, "scalar_{}", self.dtype);
            return;
        }
        for d in &self.shape {
            let _ = write!(out, "{d}x");
        }
        out.push_str(self.dtype.mlir_name());
    }
}

impl fmt::Display for TensorType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tensor<")?;
        for d in &self.shape {
            write!(f, "{d}x")?;
        }
        write!(f, "{}>", self.dtype)
    }
}

/// An SSA value's type.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Type {
    /// Ranked tensor (the common case for `xpu` ops).
    Tensor(TensorType),
    /// Loop induction variables and memref indices (`affine` dialect).
    Index,
    /// Scalar element value produced by `affine.load` etc.
    Scalar(DType),
    /// A buffer in accelerator memory: `memref<2x3xf32>`. Used after
    /// bufferization in the lowering pipeline.
    MemRef(TensorType),
}

impl Type {
    pub fn tensor(shape: Vec<i64>, dtype: DType) -> Type {
        Type::Tensor(TensorType::new(shape, dtype))
    }

    pub fn as_tensor(&self) -> Option<&TensorType> {
        match self {
            Type::Tensor(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_memref(&self) -> Option<&TensorType> {
        match self {
            Type::MemRef(t) => Some(t),
            _ => None,
        }
    }

    /// dtype if the type carries one.
    pub fn dtype(&self) -> Option<DType> {
        match self {
            Type::Tensor(t) | Type::MemRef(t) => Some(t.dtype),
            Type::Scalar(d) => Some(*d),
            Type::Index => None,
        }
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::Tensor(t) => write!(f, "{t}"),
            Type::Index => write!(f, "index"),
            Type::Scalar(d) => write!(f, "{d}"),
            Type::MemRef(t) => {
                write!(f, "memref<")?;
                for d in &t.shape {
                    write!(f, "{d}x")?;
                }
                write!(f, "{}>", t.dtype)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::F32, DType::BF16, DType::F16, DType::I32, DType::I8, DType::I1] {
            assert_eq!(DType::parse(d.mlir_name()), Some(d));
        }
        assert_eq!(DType::parse("f64"), None);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::I8.size_bytes(), 1);
    }

    #[test]
    fn tensor_type_display() {
        let t = TensorType::new(vec![1, 128, 768], DType::F32);
        assert_eq!(t.to_string(), "tensor<1x128x768xf32>");
        assert_eq!(t.shape_token(), "1x128x768xf32");
        assert_eq!(t.rank(), 3);
        assert_eq!(t.num_elements(), 98304);
        assert_eq!(t.size_bytes(), 98304 * 4);
    }

    #[test]
    fn scalar_tensor_token() {
        let t = TensorType::new(vec![], DType::BF16);
        assert_eq!(t.shape_token(), "scalar_bf16");
        assert_eq!(t.num_elements(), 1);
    }

    #[test]
    fn type_display() {
        assert_eq!(Type::Index.to_string(), "index");
        assert_eq!(Type::Scalar(DType::F32).to_string(), "f32");
        assert_eq!(
            Type::MemRef(TensorType::new(vec![4, 4], DType::I8)).to_string(),
            "memref<4x4xi8>"
        );
    }
}

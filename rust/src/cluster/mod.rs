//! Cluster tier: one logical prediction cache across a fleet of
//! coordinator nodes.
//!
//! Autotuning fleets duplicate probes not just across threads but across
//! *processes*: two coordinator nodes behind a load balancer each pay
//! for the same prediction. This module extends PR 1's shard-by-high-
//! bits `PredictionCache` scheme across the network: a consistent-hash
//! [`Ring`] (static membership, identical on every node) assigns each
//! `cache_key` an owner node, and the serving path consults the owner
//! before computing:
//!
//! - a **locally-owned** key runs through the single-node path untouched;
//! - a **remote-owned** key that misses the local cache is first looked
//!   up at its owner (`cache_get` over the line protocol, executed by
//!   the [`Peer`] pool's worker threads — never by an IO thread), and a
//!   value computed locally is written back to the owner asynchronously
//!   (`cache_put`), so the same probe is computed once *anywhere* in the
//!   cluster;
//! - a **Down** owner degrades the key to local-compute-plus-local-cache
//!   — a dead peer costs duplicated work, never an error.
//!
//! Membership is static: `--peers host:port,...` names every node in the
//! cluster (the serving addresses double as ring node ids) and
//! `--node-id` names this node's own entry. Gossip membership and
//! replication factor > 1 are ROADMAP follow-ons.

pub mod peer;
pub mod ring;

pub use peer::{Peer, PeerHealth, PeerReply};
pub use ring::Ring;

use crate::json::Json;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Static cluster membership for one node.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Every node in the cluster, as `host:port` serving addresses
    /// (including this node). All nodes must be configured with the same
    /// set — the ring is derived from it deterministically.
    pub members: Vec<String>,
    /// This node's own entry in `members`.
    pub self_id: String,
    /// Virtual ring points per node.
    pub vnodes: usize,
}

impl ClusterConfig {
    /// Parse the `--peers a,b,c` / `--node-id a` flag pair. `node_id` is
    /// appended to the member set if the peers list omitted it, so
    /// `--peers` may list either the full cluster or just the *other*
    /// nodes.
    pub fn new(peers: &str, node_id: &str) -> Result<ClusterConfig> {
        let node_id = node_id.trim();
        if node_id.is_empty() {
            return Err(anyhow!("--node-id must be this node's host:port"));
        }
        let mut members: Vec<String> = peers
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if !members.iter().any(|m| m == node_id) {
            members.push(node_id.to_string());
        }
        Ok(ClusterConfig {
            members,
            self_id: node_id.to_string(),
            vnodes: ring::DEFAULT_VNODES,
        })
    }
}

/// One node's view of the cluster: the shared ring plus a lazy peer
/// connection pool for every *other* member.
pub struct Cluster {
    ring: Ring,
    self_index: usize,
    /// Indexed like `ring.nodes()`; `None` at `self_index`.
    peers: Vec<Option<Arc<Peer>>>,
}

impl Cluster {
    pub fn new(cfg: &ClusterConfig) -> Result<Cluster> {
        if cfg.members.is_empty() {
            return Err(anyhow!("cluster membership is empty"));
        }
        let ring = Ring::new(&cfg.members, cfg.vnodes);
        let self_index = ring
            .index_of(&cfg.self_id)
            .ok_or_else(|| anyhow!("--node-id '{}' is not in the member list", cfg.self_id))?;
        let peers = ring
            .nodes()
            .iter()
            .enumerate()
            .map(|(i, node)| {
                if i == self_index {
                    None
                } else {
                    Some(Peer::start(node.clone()))
                }
            })
            .collect();
        Ok(Cluster { ring, self_index, peers })
    }

    pub fn ring(&self) -> &Ring {
        &self.ring
    }

    pub fn self_id(&self) -> &str {
        self.ring.node(self.self_index)
    }

    /// Does this node own `key`?
    pub fn owns(&self, key: u64) -> bool {
        self.ring.owner_index(key) == self.self_index
    }

    /// The peer owning `key`, or `None` when this node is the owner.
    pub fn owner_peer(&self, key: u64) -> Option<&Arc<Peer>> {
        let idx = self.ring.owner_index(key);
        if idx == self.self_index {
            None
        } else {
            self.peers[idx].as_ref()
        }
    }

    /// Every remote peer (for stats and tests).
    pub fn peers(&self) -> impl Iterator<Item = &Arc<Peer>> {
        self.peers.iter().flatten()
    }

    /// Per-peer view for the `stats` command.
    pub fn stats_json(&self) -> Json {
        let peers: Vec<Json> = self
            .peers()
            .map(|p| {
                Json::obj()
                    .with("addr", Json::str(p.addr()))
                    .with("state", Json::str(p.health().name()))
                    .with("in_flight", Json::num(p.in_flight() as f64))
                    .with("failures", Json::num(p.failures() as f64))
            })
            .collect();
        Json::obj()
            .with("node_id", Json::str(self.self_id()))
            .with("nodes", Json::num(self.ring.len() as f64))
            .with("peers", Json::Arr(peers))
    }

    /// Shut down every peer's worker pool (bounded; peer IO is
    /// timeout-guarded).
    pub fn shutdown(&self) {
        for p in self.peers() {
            p.shutdown();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_appends_self_and_trims() {
        let cfg = ClusterConfig::new(" a:1 , b:2 ,", "c:3").unwrap();
        assert_eq!(cfg.members, vec!["a:1", "b:2", "c:3"]);
        assert_eq!(cfg.self_id, "c:3");
        let cfg2 = ClusterConfig::new("a:1,b:2,c:3", "b:2").unwrap();
        assert_eq!(cfg2.members.len(), 3, "self already listed must not duplicate");
        assert!(ClusterConfig::new("a:1", "").is_err());
    }

    #[test]
    fn single_node_cluster_owns_every_key() {
        let cfg = ClusterConfig::new("", "a:1").unwrap();
        let c = Cluster::new(&cfg).unwrap();
        for key in [0u64, 1, u64::MAX, 0xdead_beef] {
            assert!(c.owns(key));
            assert!(c.owner_peer(key).is_none());
        }
        assert_eq!(c.peers().count(), 0);
    }

    #[test]
    fn routing_matches_the_ring() {
        let cfg = ClusterConfig::new("a:1,b:2,c:3", "b:2").unwrap();
        let c = Cluster::new(&cfg).unwrap();
        assert_eq!(c.self_id(), "b:2");
        assert_eq!(c.peers().count(), 2);
        let mut local = 0;
        let mut remote = 0;
        for i in 0..1000u64 {
            let key = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let owner = c.ring().owner(key).to_string();
            match c.owner_peer(key) {
                None => {
                    assert_eq!(owner, "b:2");
                    assert!(c.owns(key));
                    local += 1;
                }
                Some(p) => {
                    assert_eq!(p.addr(), owner);
                    assert!(!c.owns(key));
                    remote += 1;
                }
            }
        }
        assert!(local > 0 && remote > 0, "both routes must occur: {local}/{remote}");
        c.shutdown();
    }

    #[test]
    fn stats_json_shape() {
        let cfg = ClusterConfig::new("a:1,b:2,c:3", "a:1").unwrap();
        let c = Cluster::new(&cfg).unwrap();
        let j = c.stats_json();
        assert_eq!(j.req_str("node_id").unwrap(), "a:1");
        assert_eq!(j.req_f64("nodes").unwrap(), 3.0);
        let peers = j.req_arr("peers").unwrap();
        assert_eq!(peers.len(), 2);
        for p in peers {
            assert!(p.get("addr").is_some());
            assert_eq!(p.req_str("state").unwrap(), "up");
            assert_eq!(p.req_f64("in_flight").unwrap(), 0.0);
            assert_eq!(p.req_f64("failures").unwrap(), 0.0);
        }
        c.shutdown();
    }
}

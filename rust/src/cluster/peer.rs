//! Peer connection pool: one [`Peer`] per remote cluster node, built on
//! the line-protocol [`Client`].
//!
//! The pool exists so the serving path can consult a remote node's cache
//! without ever touching a peer socket from an IO thread: callers enqueue
//! a request ([`Peer::begin_get`] / [`Peer::put`]) with a nonblocking
//! `try_send` and (for gets) park on a plain channel; a small pool of
//! worker threads per peer owns the actual TCP connections and does the
//! blocking `cache_get`/`cache_put` roundtrips. The [`Client`] they ride
//! carries its own hardening — connect timeout, reconnect-once on a
//! broken pipe — so a peer restart costs one reconnect, not an error.
//!
//! Health is a three-state machine driven by consecutive attempt
//! failures:
//!
//! - **Up** — no recent failures; requests flow.
//! - **Degraded** — 1..[`DOWN_AFTER`] consecutive failures; requests
//!   still flow (the next success resets to Up).
//! - **Down** — ≥ [`DOWN_AFTER`] consecutive failures; requests fail
//!   *fast* (no socket attempt, no queueing) until an exponential
//!   backoff expires, then exactly one half-open probe is let through.
//!   A probe success resets to Up; a failure re-arms the backoff.
//!
//! A Down peer is therefore worth approximately zero latency to callers:
//! the serving path sees an immediate `None` and degrades to
//! local-compute-plus-local-cache (counted as `degraded_fallbacks` in
//! the service stats). Requests that were submitted are tracked in a
//! per-peer in-flight table (request id → cache key) until their worker
//! resolves them, which the `stats` command surfaces per peer.

use crate::coordinator::server::Client;
use crate::pred::PredVec;
use fxhash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Consecutive attempt failures after which a peer is Down (fail-fast).
pub const DOWN_AFTER: u32 = 3;

/// Connect timeout for peer sockets. Short: a peer that cannot accept
/// within this is better served by the degraded local path.
pub const PEER_CONNECT_TIMEOUT: Duration = Duration::from_millis(400);

/// Read/write timeout on established peer connections, so a hung or
/// slow (not dead) peer bounds every worker roundtrip. Deliberately
/// aligned with the serving path's caller-side probe deadline
/// (`REMOTE_GET_TIMEOUT`): a peer that repeatedly answers slower than
/// the serving path will wait accumulates *worker-side* failures, flips
/// Down, and then fails fast — slowness degrades exactly like death.
pub const PEER_IO_TIMEOUT: Duration = Duration::from_millis(500);

/// First Down backoff; doubles per further failure up to [`BACKOFF_MAX`].
const BACKOFF_BASE: Duration = Duration::from_millis(250);
const BACKOFF_MAX: Duration = Duration::from_secs(4);

/// Queued-request bound per peer. `try_send` beyond this drops the
/// request (gets degrade locally, write-backs are best-effort) instead
/// of growing a backlog behind a slow peer.
const QUEUE_DEPTH: usize = 1024;

/// Worker threads (= pooled connections) per peer.
const WORKERS_PER_PEER: usize = 2;

/// Coarse health of one peer, derived from consecutive failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerHealth {
    Up,
    Degraded,
    Down,
}

impl PeerHealth {
    pub fn name(self) -> &'static str {
        match self {
            PeerHealth::Up => "up",
            PeerHealth::Degraded => "degraded",
            PeerHealth::Down => "down",
        }
    }
}

/// Outcome of a remote cache probe that was actually attempted.
/// `Found` carries the full characteristic vector ([`PredVec`] is
/// `Copy`, so this enum keeps its `Copy` derive and channel sends stay
/// allocation-free).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PeerReply {
    /// The owner had the value.
    Found(PredVec),
    /// The owner answered but had no entry (compute locally, write back).
    NotFound,
    /// The attempt failed (connect/roundtrip error or timeout); peer
    /// health has been updated. Degrade to local compute.
    Failed,
}

enum PeerReq {
    Get { id: u64, key: u64, respond: Sender<PeerReply> },
    Put { id: u64, key: u64, value: PredVec },
}

struct HealthInner {
    consecutive_failures: u32,
    /// While Down: when the next half-open probe may go out.
    retry_at: Option<Instant>,
}

/// One remote node: a bounded request queue, a worker pool owning the
/// sockets, a health state machine, and an in-flight request table.
pub struct Peer {
    addr: String,
    tx: Mutex<Option<SyncSender<PeerReq>>>,
    health: Mutex<HealthInner>,
    /// In-flight request table: internal request id → cache key, from
    /// submit until the owning worker resolves the request. Today only
    /// its size is exported (`in_flight()` / the stats `cluster` view) —
    /// the key mapping is kept for debuggability and as the anchor for
    /// the cluster-wide single-flight follow-on; the two uncontended
    /// lock touches per request are noise next to the TCP roundtrip
    /// every entry represents.
    inflight: Mutex<FxHashMap<u64, u64>>,
    seq: AtomicU64,
    /// Failed attempts over the peer's lifetime (not consecutive).
    failures_total: AtomicU64,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

fn backoff(consecutive: u32) -> Duration {
    let exp = consecutive.saturating_sub(DOWN_AFTER).min(4);
    (BACKOFF_BASE * (1u32 << exp)).min(BACKOFF_MAX)
}

impl Peer {
    /// Spawn the worker pool for one remote node. Connections are opened
    /// lazily on first use — the peer process may not be up yet.
    pub fn start(addr: String) -> Arc<Peer> {
        let (tx, rx) = sync_channel::<PeerReq>(QUEUE_DEPTH);
        let peer = Arc::new(Peer {
            addr,
            tx: Mutex::new(Some(tx)),
            health: Mutex::new(HealthInner { consecutive_failures: 0, retry_at: None }),
            inflight: Mutex::new(FxHashMap::default()),
            seq: AtomicU64::new(1),
            failures_total: AtomicU64::new(0),
            workers: Mutex::new(Vec::new()),
        });
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = peer.workers.lock().unwrap();
        for _ in 0..WORKERS_PER_PEER {
            let peer2 = peer.clone();
            let rx2 = rx.clone();
            workers.push(std::thread::spawn(move || worker_loop(peer2, rx2)));
        }
        drop(workers);
        peer
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn health(&self) -> PeerHealth {
        let h = self.health.lock().unwrap();
        match h.consecutive_failures {
            0 => PeerHealth::Up,
            n if n < DOWN_AFTER => PeerHealth::Degraded,
            _ => PeerHealth::Down,
        }
    }

    /// Requests submitted but not yet resolved by a worker.
    pub fn in_flight(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// Failed attempts over the peer's lifetime.
    pub fn failures(&self) -> u64 {
        self.failures_total.load(Ordering::Relaxed)
    }

    /// Would a request submitted now be attempted? Down peers inside
    /// their backoff window answer `false` (callers fail fast). Does not
    /// consume the half-open probe — that happens worker-side.
    fn accepting(&self) -> bool {
        let h = self.health.lock().unwrap();
        if h.consecutive_failures < DOWN_AFTER {
            return true;
        }
        match h.retry_at {
            Some(t) => Instant::now() >= t,
            None => true,
        }
    }

    /// Worker-side gate: like [`Peer::accepting`], but claims the
    /// half-open probe slot (pushes `retry_at` out) so a Down peer gets
    /// exactly one attempt per backoff window, not one per queued
    /// request.
    fn attempt_allowed(&self) -> bool {
        let mut h = self.health.lock().unwrap();
        if h.consecutive_failures < DOWN_AFTER {
            return true;
        }
        match h.retry_at {
            Some(t) if Instant::now() < t => false,
            _ => {
                let n = h.consecutive_failures;
                h.retry_at = Some(Instant::now() + backoff(n));
                true
            }
        }
    }

    fn record_failure(&self) {
        self.failures_total.fetch_add(1, Ordering::Relaxed);
        let mut h = self.health.lock().unwrap();
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        if h.consecutive_failures >= DOWN_AFTER {
            h.retry_at = Some(Instant::now() + backoff(h.consecutive_failures));
        }
    }

    fn record_success(&self) {
        let mut h = self.health.lock().unwrap();
        h.consecutive_failures = 0;
        h.retry_at = None;
    }

    /// Nonblocking remote-get submit. `None` means no attempt will be
    /// made (peer Down in backoff, queue full, or pool shut down) — the
    /// caller should fall back to local compute immediately. `Some(rx)`
    /// resolves to the attempt's [`PeerReply`].
    pub fn begin_get(&self, key: u64) -> Option<Receiver<PeerReply>> {
        if !self.accepting() {
            return None;
        }
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref()?;
        let id = self.seq.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.inflight.lock().unwrap().insert(id, key);
        match tx.try_send(PeerReq::Get { id, key, respond: rtx }) {
            Ok(()) => Some(rrx),
            Err(_) => {
                self.inflight.lock().unwrap().remove(&id);
                None
            }
        }
    }

    /// Blocking remote get with a caller-side deadline. `None` = no
    /// attempt was made (fail-fast); `Some(Failed)` covers both attempt
    /// errors and the deadline expiring first.
    pub fn get(&self, key: u64, timeout: Duration) -> Option<PeerReply> {
        let rx = self.begin_get(key)?;
        Some(rx.recv_timeout(timeout).unwrap_or(PeerReply::Failed))
    }

    /// Fire-and-forget write-back. Returns whether the put was enqueued
    /// (a Down peer or a full queue drops it — the value is still in the
    /// local cache, so losing a write-back costs one recompute at worst).
    pub fn put(&self, key: u64, value: PredVec) -> bool {
        if !self.accepting() {
            return false;
        }
        let guard = self.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else { return false };
        let id = self.seq.fetch_add(1, Ordering::Relaxed);
        self.inflight.lock().unwrap().insert(id, key);
        match tx.try_send(PeerReq::Put { id, key, value }) {
            Ok(()) => true,
            Err(_) => {
                self.inflight.lock().unwrap().remove(&id);
                false
            }
        }
    }

    /// Drop the request queue and join the workers. Bounded: workers'
    /// socket calls all carry timeouts.
    pub fn shutdown(&self) {
        *self.tx.lock().unwrap() = None;
        for j in self.workers.lock().unwrap().drain(..) {
            let _ = j.join();
        }
    }

    // ---- worker side ----

    fn ensure_conn(&self, conn: &mut Option<Client>) -> bool {
        if conn.is_some() {
            return true;
        }
        match Client::connect_timeout(&self.addr, PEER_CONNECT_TIMEOUT) {
            Ok(mut c) => {
                // Bound every roundtrip: a hung peer must not pin a
                // worker (or shutdown) indefinitely.
                if c.set_io_timeout(Some(PEER_IO_TIMEOUT)).is_err() {
                    self.record_failure();
                    return false;
                }
                *conn = Some(c);
                true
            }
            Err(_) => {
                self.record_failure();
                false
            }
        }
    }

    fn attempt_get(&self, conn: &mut Option<Client>, key: u64) -> PeerReply {
        if !self.ensure_conn(conn) {
            return PeerReply::Failed;
        }
        match conn.as_mut().unwrap().cache_get(key) {
            Ok(Some(v)) => {
                self.record_success();
                PeerReply::Found(v)
            }
            Ok(None) => {
                self.record_success();
                PeerReply::NotFound
            }
            Err(_) => {
                *conn = None;
                self.record_failure();
                PeerReply::Failed
            }
        }
    }

    fn attempt_put(&self, conn: &mut Option<Client>, key: u64, value: PredVec) {
        if !self.ensure_conn(conn) {
            return;
        }
        match conn.as_mut().unwrap().cache_put(key, value) {
            Ok(()) => self.record_success(),
            Err(_) => {
                *conn = None;
                self.record_failure();
            }
        }
    }

    fn process(&self, conn: &mut Option<Client>, req: PeerReq) {
        // Fail queued requests fast while Down: one half-open probe per
        // backoff window pays the connect timeout, the rest do not.
        let allowed = self.attempt_allowed();
        match req {
            PeerReq::Get { id, key, respond } => {
                let reply =
                    if allowed { self.attempt_get(conn, key) } else { PeerReply::Failed };
                self.inflight.lock().unwrap().remove(&id);
                let _ = respond.send(reply);
            }
            PeerReq::Put { id, key, value } => {
                if allowed {
                    self.attempt_put(conn, key, value);
                }
                self.inflight.lock().unwrap().remove(&id);
            }
        }
    }
}

/// Worker: take one request at a time off the shared queue (the mutex is
/// only held while parked on `recv`, not while doing socket IO) and
/// resolve it over this worker's own connection.
fn worker_loop(peer: Arc<Peer>, rx: Arc<Mutex<Receiver<PeerReq>>>) {
    let mut conn: Option<Client> = None;
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => break, // queue dropped: shutdown
            }
        };
        peer.process(&mut conn, req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{parse, Json};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    /// Minimal in-test cluster node: accepts connections and serves
    /// `cache_get`/`cache_put` against a shared map (values are full
    /// characteristic vectors, spoken as JSON arrays on the wire). One
    /// thread per connection; threads end when the test's sockets close.
    fn spawn_fake_node(
        drop_first_conn: bool,
    ) -> (String, Arc<Mutex<FxHashMap<u64, PredVec>>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let store: Arc<Mutex<FxHashMap<u64, PredVec>>> =
            Arc::new(Mutex::new(FxHashMap::default()));
        let store2 = store.clone();
        std::thread::spawn(move || {
            let mut first = true;
            for conn in listener.incoming() {
                let Ok(conn) = conn else { break };
                if drop_first_conn && std::mem::take(&mut first) {
                    drop(conn); // simulate a node that accepts then dies
                    continue;
                }
                let store = store2.clone();
                std::thread::spawn(move || {
                    let mut writer = conn.try_clone().unwrap();
                    let reader = BufReader::new(conn);
                    for line in reader.lines() {
                        let Ok(line) = line else { return };
                        let req = parse(&line).unwrap();
                        let id = req.get("id").cloned().unwrap_or(Json::Null);
                        let key = req
                            .get("key")
                            .and_then(Json::as_str)
                            .and_then(|s| u64::from_str_radix(s, 16).ok())
                            .unwrap();
                        let resp = match req.get("cmd").and_then(Json::as_str) {
                            Some("cache_get") => match store.lock().unwrap().get(&key) {
                                Some(&v) => Json::obj()
                                    .with("id", id)
                                    .with("ok", Json::Bool(true))
                                    .with("found", Json::Bool(true))
                                    .with("value", v.to_json()),
                                None => Json::obj()
                                    .with("id", id)
                                    .with("ok", Json::Bool(true))
                                    .with("found", Json::Bool(false)),
                            },
                            Some("cache_put") => {
                                let v =
                                    PredVec::from_json(req.req("value").unwrap()).unwrap();
                                store.lock().unwrap().insert(key, v);
                                Json::obj()
                                    .with("id", id)
                                    .with("ok", Json::Bool(true))
                                    .with("stored", Json::Bool(true))
                            }
                            other => panic!("fake node got unexpected cmd {other:?}"),
                        };
                        writer.write_all(resp.to_string().as_bytes()).unwrap();
                        writer.write_all(b"\n").unwrap();
                    }
                });
            }
        });
        (addr, store)
    }

    /// An address with nothing listening (bind, read the port, drop).
    fn dead_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    }

    #[test]
    fn get_and_put_roundtrip_against_fake_node() {
        let (addr, store) = spawn_fake_node(false);
        let peer = Peer::start(addr);
        // Miss first.
        assert_eq!(peer.get(7, Duration::from_secs(2)), Some(PeerReply::NotFound));
        // Write-back lands (fire-and-forget → poll the store). The value
        // is a 2-wide characteristic vector: it must survive the wire
        // as an array, element for element.
        let vec2 = PredVec::from_slice(&[2.5, 93.0]);
        assert!(peer.put(7, vec2));
        let t0 = Instant::now();
        while store.lock().unwrap().get(&7).is_none() {
            assert!(t0.elapsed() < Duration::from_secs(2), "put never reached the node");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(store.lock().unwrap().get(&7), Some(&vec2));
        // Now the get hits, returning the full vector.
        assert_eq!(peer.get(7, Duration::from_secs(2)), Some(PeerReply::Found(vec2)));
        assert_eq!(peer.health(), PeerHealth::Up);
        assert_eq!(peer.failures(), 0);
        // The in-flight table drains once everything resolved.
        let t0 = Instant::now();
        while peer.in_flight() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(2), "in-flight table leaked");
            std::thread::sleep(Duration::from_millis(5));
        }
        peer.shutdown();
    }

    /// The satellite's "accepts then closes" case: the first connection
    /// dies under the pool; the Client's reconnect-once retry makes the
    /// probe succeed anyway, and the peer never leaves Up.
    #[test]
    fn first_connection_dropped_is_absorbed_by_client_retry() {
        let (addr, store) = spawn_fake_node(true);
        store.lock().unwrap().insert(42, PredVec::scalar(6.25));
        let peer = Peer::start(addr);
        assert_eq!(
            peer.get(42, Duration::from_secs(2)),
            Some(PeerReply::Found(PredVec::scalar(6.25)))
        );
        assert_eq!(peer.health(), PeerHealth::Up);
        assert_eq!(peer.failures(), 0, "the dropped conn must be retried, not counted");
        peer.shutdown();
    }

    #[test]
    fn dead_peer_goes_down_and_fails_fast() {
        let peer = Peer::start(dead_addr());
        // Three sequential attempts (connect refused is immediate).
        for _ in 0..DOWN_AFTER {
            assert_eq!(peer.get(1, Duration::from_secs(2)), Some(PeerReply::Failed));
        }
        assert_eq!(peer.health(), PeerHealth::Down);
        assert!(peer.failures() >= DOWN_AFTER as u64);
        // Inside the backoff window: no attempt, no queueing, no waiting.
        let t0 = Instant::now();
        assert!(peer.begin_get(1).is_none(), "down peer must fail fast");
        assert!(!peer.put(1, PredVec::scalar(1.0)), "down peer must drop write-backs");
        assert!(t0.elapsed() < Duration::from_millis(100));
        peer.shutdown();
    }

    #[test]
    fn health_machine_degrades_recovers_and_half_opens() {
        let peer = Peer::start(dead_addr());
        assert_eq!(peer.health(), PeerHealth::Up);
        peer.record_failure();
        assert_eq!(peer.health(), PeerHealth::Degraded);
        // A success anywhere short of Down resets fully.
        peer.record_success();
        assert_eq!(peer.health(), PeerHealth::Up);
        for _ in 0..DOWN_AFTER {
            peer.record_failure();
        }
        assert_eq!(peer.health(), PeerHealth::Down);
        assert!(!peer.accepting(), "fresh Down must be inside its backoff");
        // Force the backoff window into the past: the half-open probe
        // opens, and claiming it (worker-side gate) closes it again.
        peer.health.lock().unwrap().retry_at =
            Some(Instant::now() - Duration::from_millis(1));
        assert!(peer.accepting(), "expired backoff must allow a probe");
        assert!(peer.attempt_allowed(), "first claimant takes the probe");
        assert!(!peer.attempt_allowed(), "probe slot must be single-use per window");
        peer.record_success();
        assert_eq!(peer.health(), PeerHealth::Up);
        peer.shutdown();
    }

    #[test]
    fn backoff_grows_and_caps() {
        assert_eq!(backoff(DOWN_AFTER), BACKOFF_BASE);
        assert_eq!(backoff(DOWN_AFTER + 1), BACKOFF_BASE * 2);
        assert!(backoff(DOWN_AFTER + 20) <= BACKOFF_MAX);
    }

    #[test]
    fn shutdown_joins_workers_and_rejects_new_requests() {
        let (addr, _store) = spawn_fake_node(false);
        let peer = Peer::start(addr);
        peer.shutdown();
        assert!(peer.begin_get(1).is_none());
        assert!(!peer.put(1, PredVec::scalar(1.0)));
    }
}

//! Consistent-hash ring: the ownership map of the cluster tier.
//!
//! PR 1's `PredictionCache` spreads keys over in-process shards by their
//! high bits; this ring extends the same idea *across processes*. Every
//! node in the cluster builds the identical ring from the identical
//! (static) membership list, so all nodes agree — with no coordination
//! traffic — on which node owns any given `cache_key`. Ownership decides
//! where a prediction is cached cluster-wide: the owner's cache is the
//! one consulted before computing and the one written back to after.
//!
//! Construction hashes `(node_id, replica)` with FxHash for
//! [`DEFAULT_VNODES`] virtual points per node; lookup is a binary search
//! for the first point at or past the key (wrapping at the top of the
//! u64 space, so a key's owner is effectively chosen by its high bits
//! first). Virtual nodes keep the load split near-even, and membership
//! changes move only the keys whose owning arc changed — both properties
//! are pinned by the tests below.
//!
//! Membership is static (`--peers` + `--node-id` at startup): node death
//! is handled by the peer pool's health state (degrade to local compute),
//! not by ring surgery. Gossip membership is a ROADMAP follow-on.

use fxhash::FxHasher;
use std::hash::{Hash, Hasher};

/// Virtual points per node. 64 keeps the max/min node share within a few
/// tens of percent for small clusters while construction stays trivial.
pub const DEFAULT_VNODES: usize = 64;

/// An immutable consistent-hash ring over a set of node ids.
///
/// Node ids are the nodes' serving addresses (`host:port`); the id list
/// is sorted and deduplicated at construction so every node derives the
/// exact same ring regardless of the order its `--peers` flag listed
/// them in.
pub struct Ring {
    /// `(point, node index)` sorted by point; ties (astronomically rare)
    /// break by node index, which is itself deterministic.
    points: Vec<(u64, u32)>,
    nodes: Vec<String>,
}

fn point_hash(node: &str, replica: usize) -> u64 {
    let mut h = FxHasher::default();
    node.hash(&mut h);
    (replica as u64).hash(&mut h);
    h.finish()
}

impl Ring {
    /// Build a ring over `members` with `vnodes` virtual points each.
    /// Panics on an empty membership — a cluster has at least this node.
    pub fn new(members: &[String], vnodes: usize) -> Ring {
        let mut nodes: Vec<String> = members.to_vec();
        nodes.sort();
        nodes.dedup();
        assert!(!nodes.is_empty(), "consistent-hash ring needs at least one node");
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(nodes.len() * vnodes);
        for (i, node) in nodes.iter().enumerate() {
            for replica in 0..vnodes {
                points.push((point_hash(node, replica), i as u32));
            }
        }
        points.sort_unstable();
        Ring { points, nodes }
    }

    /// Index (into [`Ring::nodes`]) of the node owning `key`: the first
    /// ring point at or past the key, wrapping past the top of the ring.
    pub fn owner_index(&self, key: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < key);
        let i = if i == self.points.len() { 0 } else { i };
        self.points[i].1 as usize
    }

    /// Node id owning `key`.
    pub fn owner(&self, key: u64) -> &str {
        &self.nodes[self.owner_index(key)]
    }

    /// Sorted, deduplicated membership this ring was built from.
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// Node id at `index` (as returned by [`Ring::owner_index`]).
    pub fn node(&self, index: usize) -> &str {
        &self.nodes[index]
    }

    /// Ring index of a node id, if it is a member.
    pub fn index_of(&self, id: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n == id)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(ids: &[&str]) -> Vec<String> {
        ids.iter().map(|s| s.to_string()).collect()
    }

    /// Spread sample keys the way real cache keys are spread: hashed.
    fn sample_keys(n: usize) -> Vec<u64> {
        (0..n as u64)
            .map(|i| {
                let mut h = FxHasher::default();
                i.hash(&mut h);
                h.finish()
            })
            .collect()
    }

    #[test]
    fn single_node_owns_everything() {
        let ring = Ring::new(&members(&["a:1"]), DEFAULT_VNODES);
        for key in sample_keys(100) {
            assert_eq!(ring.owner(key), "a:1");
        }
    }

    #[test]
    fn ownership_is_deterministic_and_order_independent() {
        let a = Ring::new(&members(&["n1:7071", "n2:7071", "n3:7071"]), DEFAULT_VNODES);
        let b = Ring::new(&members(&["n3:7071", "n1:7071", "n2:7071"]), DEFAULT_VNODES);
        // Duplicates in the list must not skew the ring either.
        let c = Ring::new(
            &members(&["n2:7071", "n2:7071", "n1:7071", "n3:7071"]),
            DEFAULT_VNODES,
        );
        for key in sample_keys(1000) {
            let owner = a.owner(key);
            assert_eq!(owner, b.owner(key), "membership order changed ownership");
            assert_eq!(owner, c.owner(key), "duplicate members changed ownership");
        }
        assert_eq!(a.nodes(), b.nodes());
        assert_eq!(a.len(), 3);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn load_is_roughly_balanced() {
        let ring = Ring::new(&members(&["a:1", "b:2", "c:3"]), DEFAULT_VNODES);
        let keys = sample_keys(30_000);
        let mut counts = [0usize; 3];
        for &k in &keys {
            counts[ring.owner_index(k)] += 1;
        }
        // 64 vnodes keeps every node within a loose band around the
        // 1/3 mean; the bound is deliberately generous (the test pins
        // "no node is starved or doubled", not a tight variance).
        for (i, &c) in counts.iter().enumerate() {
            let share = c as f64 / keys.len() as f64;
            assert!(
                (0.15..=0.55).contains(&share),
                "node {i} owns {share:.3} of the keyspace: {counts:?}"
            );
        }
    }

    #[test]
    fn removing_a_node_only_moves_its_own_keys() {
        let full = Ring::new(&members(&["a:1", "b:2", "c:3", "d:4"]), DEFAULT_VNODES);
        let without_c = Ring::new(&members(&["a:1", "b:2", "d:4"]), DEFAULT_VNODES);
        let keys = sample_keys(20_000);
        let mut moved = 0usize;
        for &k in &keys {
            let before = full.owner(k);
            let after = without_c.owner(k);
            if before == "c:3" {
                moved += 1;
                assert_ne!(after, "c:3");
            } else {
                // Minimal churn: every key NOT owned by the removed node
                // keeps its owner.
                assert_eq!(before, after, "key not owned by c:3 moved on removal");
            }
        }
        // Sanity: the removed node did own a nontrivial share.
        assert!(moved > keys.len() / 10, "c:3 owned suspiciously few keys: {moved}");
    }

    #[test]
    fn adding_a_node_only_steals_keys_for_itself() {
        let small = Ring::new(&members(&["a:1", "b:2"]), DEFAULT_VNODES);
        let grown = Ring::new(&members(&["a:1", "b:2", "c:3"]), DEFAULT_VNODES);
        for &k in &sample_keys(20_000) {
            let before = small.owner(k);
            let after = grown.owner(k);
            if before != after {
                assert_eq!(after, "c:3", "growth moved a key to a pre-existing node");
            }
        }
    }

    #[test]
    fn index_lookup_roundtrips() {
        let ring = Ring::new(&members(&["b:2", "a:1"]), 4);
        // Sorted membership: a:1 first.
        assert_eq!(ring.node(0), "a:1");
        assert_eq!(ring.index_of("b:2"), Some(1));
        assert_eq!(ring.index_of("nope"), None);
        let k = sample_keys(1)[0];
        assert_eq!(ring.node(ring.owner_index(k)), ring.owner(k));
    }
}

//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! This image has no crates.io registry, so the workspace vendors the small
//! slice of the `anyhow` API the codebase uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Error values carry their context chain
//! as strings; `{e}` prints the outermost message, `{e:#}` the whole chain
//! joined with `": "`, matching anyhow's formatting contract closely enough
//! for log lines and tests.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what allows the blanket `From` impl that
//! powers `?` conversions from any std error type.

use std::fmt;

/// A context-chained error. The outermost context is first; the root cause
/// is last.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, outermost first.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through at {x}"))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through at 1");
    }
}

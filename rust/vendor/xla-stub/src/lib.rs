//! Offline stub for the `xla` crate (xla-rs over xla_extension 0.5.1).
//!
//! The real PJRT runtime links the native `xla_extension` C++ toolkit,
//! which is not present on this image. This crate mirrors the API surface
//! `mlir_cost::runtime` consumes so the workspace always compiles; host
//! [`Literal`] plumbing is functional, while every device entry point
//! (client creation, compile, execute) returns a clear "runtime
//! unavailable" error. The serving/training tests detect the absence of
//! compiled artifacts and skip cleanly, so `cargo test` passes end to end
//! on a stub-only image.
//!
//! To serve real predictions, swap the `xla` path dependency in
//! `rust/Cargo.toml` for the real `xla` crate — the consumed signatures
//! match.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} requires the native xla_extension runtime \
         (swap rust/vendor/xla-stub for the real `xla` crate)"
    ))
}

/// Element dtypes mirroring the real crate's enum (only F32/S32 are
/// produced by this codebase; the rest keep downstream matches honest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Shape of a dense array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Internal storage for [`Literal`]; public only because [`NativeType`]
/// mentions it.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side literal. Construction and reshape are functional; only
/// device transfer requires the real runtime.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Dtypes the stub can hold in a [`Literal`].
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        }
    }

    /// Reinterpret with new dims (element count must match; `&[]` is a
    /// scalar holding one element).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "xla stub: cannot reshape {} elements to {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error(format!("xla stub: literal holds {:?}, not {:?}", self.ty(), T::TY))
        })
    }

    /// Tuple results only come back from `execute`, which the stub cannot
    /// perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple on an executed result"))
    }
}

/// Parsed HLO module (stub: never constructible from text).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!(
            "xla stub: cannot parse HLO text {path}; the native xla_extension \
             runtime is required"
        )))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu()"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile()"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute()"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync()"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let shaped = lit.reshape(&[2, 2]).unwrap();
        let shape = shaped.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.element_type(), ElementType::F32);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(shaped.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
        // Scalar reshape of a single element.
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("xla stub"));
    }
}

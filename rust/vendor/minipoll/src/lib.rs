//! Vendored minimal epoll — the readiness engine under the serving front
//! end.
//!
//! The coordinator's TCP front end needs exactly four kernel facilities:
//! `epoll_create1` (a readiness set), `epoll_ctl` (arm/re-arm/remove fds),
//! `epoll_wait` (block until something is ready), and `eventfd` (a
//! user-space doorbell so shutdown and cross-thread handoff can wake a
//! blocked `epoll_wait` without sleeps or timeouts). mio and tokio ship
//! those same four calls wrapped in an executor this workload doesn't
//! need; this image has no crates.io registry anyway, so the bindings are
//! vendored raw (same pattern as `vendor/fxhash`): `extern "C"`
//! declarations against the libc that `std` already links, plus safe RAII
//! wrappers.
//!
//! Level-triggered only (no `EPOLLET`): the server drains sockets to
//! `WouldBlock` on every wakeup, and level-triggered re-notification is
//! the forgiving mode if a drain ever stops early.
//!
//! One socket-construction helper rides along: [`listener_reuseport`]
//! builds a `TcpListener` with `SO_REUSEPORT` set *before* bind — which
//! std's `TcpListener::bind` cannot do — so several listeners can share
//! one address and the kernel shards accepts across them.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};

// ---- readiness bits (bit-identical to <sys/epoll.h>) ----

/// Fd is readable (or a peer connected, for listeners).
pub const EPOLLIN: u32 = 0x001;
/// Fd is writable without blocking.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported; no need to request it).
pub const EPOLLERR: u32 = 0x008;
/// Hangup (always reported; no need to request it).
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its writing half (stream sockets).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

/// Kernel event record. glibc packs this struct on x86-64 (12 bytes, no
/// padding between `events` and `data`) and leaves it naturally aligned
/// elsewhere — the cfg_attr mirrors `__EPOLL_PACKED`. Fields of the
/// packed form may be unaligned: read them by value, never by reference.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct RawEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut RawEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut RawEvent, maxevents: c_int, timeout: c_int) -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn bind(fd: c_int, addr: *const c_void, len: c_uint) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
    fn setsockopt(fd: c_int, level: c_int, name: c_int, val: *const c_void, len: c_uint) -> c_int;
}

// ---- SO_REUSEPORT listener construction (values from <sys/socket.h>,
// <netinet/in.h> on Linux) ----

const AF_INET: c_int = 2;
const AF_INET6: c_int = 10;
const SOCK_STREAM: c_int = 1;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;

/// `struct sockaddr_in`, network byte order in `sin_port`/`sin_addr`.
#[repr(C)]
struct SockAddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: u32,
    sin_zero: [u8; 8],
}

/// `struct sockaddr_in6`.
#[repr(C)]
struct SockAddrIn6 {
    sin6_family: u16,
    sin6_port: u16,
    sin6_flowinfo: u32,
    sin6_addr: [u8; 16],
    sin6_scope_id: u32,
}

/// Owns a raw socket fd until it is handed to `TcpListener`; closes it
/// on every early-error return path.
struct FdGuard(RawFd);

impl Drop for FdGuard {
    fn drop(&mut self) {
        if self.0 >= 0 {
            unsafe { close(self.0) };
        }
    }
}

/// Build a listening `TcpListener` on `addr` with `SO_REUSEPORT` (and
/// `SO_REUSEADDR`, matching std) set before bind. Several listeners
/// built this way can share one address; the kernel load-balances
/// incoming connections across them. Fails with the OS error where the
/// option is unsupported — callers fall back to a normal bind.
pub fn listener_reuseport(addr: &SocketAddr, backlog: i32) -> io::Result<TcpListener> {
    let domain = if addr.is_ipv4() { AF_INET } else { AF_INET6 };
    let fd = unsafe { socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let guard = FdGuard(fd);
    let one: c_int = 1;
    let onep = &one as *const c_int as *const c_void;
    let onelen = std::mem::size_of::<c_int>() as c_uint;
    for opt in [SO_REUSEADDR, SO_REUSEPORT] {
        if unsafe { setsockopt(fd, SOL_SOCKET, opt, onep, onelen) } < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    let rc = match addr {
        SocketAddr::V4(v4) => {
            let sa = SockAddrIn {
                sin_family: AF_INET as u16,
                sin_port: v4.port().to_be(),
                // The octets are already network order; keep them as-is.
                sin_addr: u32::from_ne_bytes(v4.ip().octets()),
                sin_zero: [0; 8],
            };
            let p = &sa as *const SockAddrIn as *const c_void;
            unsafe { bind(fd, p, std::mem::size_of::<SockAddrIn>() as c_uint) }
        }
        SocketAddr::V6(v6) => {
            let sa = SockAddrIn6 {
                sin6_family: AF_INET6 as u16,
                sin6_port: v6.port().to_be(),
                sin6_flowinfo: v6.flowinfo().to_be(),
                sin6_addr: v6.ip().octets(),
                sin6_scope_id: v6.scope_id(),
            };
            let p = &sa as *const SockAddrIn6 as *const c_void;
            unsafe { bind(fd, p, std::mem::size_of::<SockAddrIn6>() as c_uint) }
        }
    };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { listen(fd, backlog) } < 0 {
        return Err(io::Error::last_os_error());
    }
    std::mem::forget(guard);
    Ok(unsafe { TcpListener::from_raw_fd(fd) })
}

/// One delivered readiness event: the interest bits that fired plus the
/// caller's 64-bit token (connection slot, doorbell id, ...).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub events: u32,
    pub token: u64,
}

impl Event {
    pub fn readable(&self) -> bool {
        self.events & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.events & EPOLLOUT != 0
    }

    /// Peer gone or fd broken: the owner should tear the fd down after
    /// draining whatever is still readable.
    pub fn closed(&self) -> bool {
        self.events & (EPOLLHUP | EPOLLERR | EPOLLRDHUP) != 0
    }
}

/// Reusable event buffer for [`Epoll::wait`] (one allocation per loop,
/// not per wakeup).
pub struct Events {
    buf: Vec<RawEvent>,
    len: usize,
}

impl Events {
    pub fn with_capacity(cap: usize) -> Events {
        Events { buf: vec![RawEvent { events: 0, data: 0 }; cap.max(1)], len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = Event> + '_ {
        // Copy out of the (possibly packed) raw record; no references
        // into it ever escape.
        self.buf[..self.len].iter().map(|raw| {
            let r = *raw;
            Event { events: r.events, token: r.data }
        })
    }
}

/// RAII epoll instance.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = RawEvent { events: interest, data: token };
        let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev as *mut RawEvent };
        if unsafe { epoll_ctl(self.fd, op, fd, arg) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Start watching `fd` for `interest`; delivered events carry `token`.
    pub fn add(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Re-arm an already-watched fd with a new interest set (e.g. add
    /// `EPOLLOUT` while a write is backed up, drop it once drained).
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Stop watching `fd`.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block until at least one fd is ready (`timeout_ms < 0` = forever,
    /// `0` = poll). Returns the number of events filled into `events`.
    /// A signal-interrupted wait (`EINTR`) is retried internally.
    pub fn wait(&self, events: &mut Events, timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(self.fd, events.buf.as_mut_ptr(), events.buf.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                events.len = n as usize;
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                events.len = 0;
                return Err(err);
            }
        }
    }
}

impl AsRawFd for Epoll {
    fn as_raw_fd(&self) -> RawFd {
        self.fd
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

// Safety: the epoll fd is just an integer handle; the kernel serializes
// epoll_ctl/epoll_wait on it. Sharing &Epoll across threads is the
// intended use (an IO thread waits while another registers a doorbell).
unsafe impl Send for Epoll {}
unsafe impl Sync for Epoll {}

/// Nonblocking eventfd doorbell: `signal()` from any thread wakes an
/// `epoll_wait` that watches it; the woken side `drain()`s it back to
/// silence. Used for shutdown and cross-thread connection handoff.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EventFd { fd })
    }

    /// Ring the doorbell. A full counter (`EAGAIN`) is success — the fd
    /// is already readable, which is all a doorbell needs.
    pub fn signal(&self) {
        let one: u64 = 1;
        let p = &one as *const u64 as *const c_void;
        unsafe { write(self.fd, p, 8) };
    }

    /// Reset to silent; returns the number of accumulated signals.
    pub fn drain(&self) -> u64 {
        let mut count: u64 = 0;
        let p = &mut count as *mut u64 as *mut c_void;
        if unsafe { read(self.fd, p, 8) } == 8 {
            count
        } else {
            0
        }
    }
}

impl AsRawFd for EventFd {
    fn as_raw_fd(&self) -> RawFd {
        self.fd
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

unsafe impl Send for EventFd {}
unsafe impl Sync for EventFd {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn raw_event_layout_matches_kernel() {
        // x86-64 packs to 12 bytes; other arches pad to 16.
        if cfg!(target_arch = "x86_64") {
            assert_eq!(std::mem::size_of::<RawEvent>(), 12);
        }
        assert!(std::mem::size_of::<RawEvent>() >= 12);
    }

    #[test]
    fn eventfd_doorbell_roundtrip() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.as_raw_fd(), EPOLLIN, 42).unwrap();
        let mut events = Events::with_capacity(8);

        // Silent doorbell: a zero-timeout poll sees nothing.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        assert!(events.is_empty());

        efd.signal();
        efd.signal(); // coalesces into the same readable counter
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        let ev = events.iter().next().unwrap();
        assert_eq!(ev.token, 42);
        assert!(ev.readable());
        assert!(!ev.closed());

        // Drain resets it; both signals were coalesced.
        assert_eq!(efd.drain(), 2);
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn signal_from_another_thread_wakes_a_blocking_wait() {
        let ep = Epoll::new().unwrap();
        let efd = std::sync::Arc::new(EventFd::new().unwrap());
        ep.add(efd.as_raw_fd(), EPOLLIN, 7).unwrap();
        let remote = efd.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            remote.signal();
        });
        let mut events = Events::with_capacity(4);
        // Blocks until the other thread rings — the shutdown-wakeup shape.
        let n = ep.wait(&mut events, 5000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events.iter().next().unwrap().token, 7);
        t.join().unwrap();
    }

    #[test]
    fn tcp_readability_and_writability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 1).unwrap();
        let mut events = Events::with_capacity(8);

        // Nothing sent yet: not readable.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        client.write_all(b"hello").unwrap();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        assert!(events.iter().next().unwrap().readable());
        let mut buf = [0u8; 16];
        let n = (&server).read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"hello");

        // An idle healthy socket is writable the moment we ask for it.
        ep.modify(server.as_raw_fd(), EPOLLOUT, 2).unwrap();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        let ev = events.iter().next().unwrap();
        assert_eq!(ev.token, 2);
        assert!(ev.writable());

        // Peer close surfaces as a closed() event under EPOLLRDHUP.
        ep.modify(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 3).unwrap();
        drop(client);
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        assert!(events.iter().next().unwrap().closed());
    }

    #[test]
    fn delete_stops_event_delivery() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.as_raw_fd(), EPOLLIN, 9).unwrap();
        efd.signal();
        let mut events = Events::with_capacity(4);
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        ep.delete(efd.as_raw_fd()).unwrap();
        // Still signaled, but no longer watched.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // Double-delete reports the kernel's ENOENT instead of panicking.
        assert!(ep.delete(efd.as_raw_fd()).is_err());
    }

    #[test]
    fn reuseport_listeners_share_one_address() {
        let a = match listener_reuseport(&"127.0.0.1:0".parse().unwrap(), 16) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("skipping: SO_REUSEPORT unsupported here ({e})");
                return;
            }
        };
        let addr = a.local_addr().unwrap();
        // Without SO_REUSEPORT on both sockets this second bind would
        // fail with EADDRINUSE.
        let b = listener_reuseport(&addr, 16).unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(a.as_raw_fd(), EPOLLIN, 0).unwrap();
        ep.add(b.as_raw_fd(), EPOLLIN, 1).unwrap();
        // The kernel picks which listener gets the connection; epoll
        // tells us which one to accept on.
        let _client = TcpStream::connect(addr).unwrap();
        let mut events = Events::with_capacity(4);
        let n = ep.wait(&mut events, 5000).unwrap();
        assert!(n >= 1);
        let ev = events.iter().next().unwrap();
        let who = if ev.token == 0 { &a } else { &b };
        let (_stream, peer) = who.accept().unwrap();
        assert_eq!(peer.ip(), addr.ip());
    }

    #[test]
    fn many_fds_one_wait() {
        let ep = Epoll::new().unwrap();
        let efds: Vec<EventFd> = (0..32).map(|_| EventFd::new().unwrap()).collect();
        for (i, e) in efds.iter().enumerate() {
            ep.add(e.as_raw_fd(), EPOLLIN, i as u64).unwrap();
        }
        for e in &efds {
            e.signal();
        }
        let mut events = Events::with_capacity(64);
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 32);
        let mut tokens: Vec<u64> = events.iter().map(|e| e.token).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, (0..32).collect::<Vec<u64>>());
    }
}

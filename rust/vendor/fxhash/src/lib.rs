//! Vendored FxHash — the rotate-xor-multiply hasher rustc uses internally.
//!
//! The serving hot path hashes on every per-token vocabulary lookup and on
//! every per-query cache/memo probe; `std`'s default SipHash is a
//! DoS-resistant streaming hash and pays for that robustness with ~4-10x
//! the latency on the short keys (op mnemonics, shape tokens, id rows)
//! this codebase feeds it. All of these tables are process-internal —
//! nothing attacker-controlled picks the keys — so the non-keyed FxHash is
//! the right trade. Vendored because this image has no crates.io registry
//! (same pattern as `vendor/anyhow`).
//!
//! The output is deterministic across runs and platforms (byte chunks are
//! read little-endian regardless of host endianness), which the cache-key
//! tests rely on.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hash, Hasher};

/// Large odd constant with high bit entropy (from rustc's FxHasher);
/// multiplication by it diffuses each mixed word across all 64 bits, so
/// the *high* bits — which the sharded cache uses for shard selection —
/// are as well mixed as the low bits the `HashMap` buckets use.
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// A fast, non-cryptographic, deterministic hasher.
#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, mut bytes: &[u8]) {
        while bytes.len() >= 8 {
            let chunk = u64::from_le_bytes(bytes[..8].try_into().expect("8-byte chunk"));
            self.add_to_hash(chunk);
            bytes = &bytes[8..];
        }
        if bytes.len() >= 4 {
            let chunk = u32::from_le_bytes(bytes[..4].try_into().expect("4-byte chunk"));
            self.add_to_hash(chunk as u64);
            bytes = &bytes[4..];
        }
        if bytes.len() >= 2 {
            let chunk = u16::from_le_bytes(bytes[..2].try_into().expect("2-byte chunk"));
            self.add_to_hash(chunk as u64);
            bytes = &bytes[2..];
        }
        if let Some(&b) = bytes.first() {
            self.add_to_hash(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add_to_hash(i as u64);
        self.add_to_hash((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`] (zero-sized, `Default`-constructed).
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed by FxHash. Construct with `FxHashMap::default()` or
/// `HashMap::with_capacity_and_hasher(n, FxBuildHasher::default())`.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed by FxHash.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

/// One-shot convenience: hash any `Hash` value to a `u64`.
pub fn hash64<T: Hash + ?Sized>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_hashers() {
        assert_eq!(hash64("xpu.matmul"), hash64("xpu.matmul"));
        assert_eq!(hash64(&[1u32, 2, 3][..]), hash64(&[1u32, 2, 3][..]));
    }

    #[test]
    fn distinct_inputs_distinct_hashes() {
        assert_ne!(hash64("xpu.matmul"), hash64("xpu.conv2d"));
        assert_ne!(hash64(&[1u32, 2][..]), hash64(&[2u32, 1][..]));
        assert_ne!(hash64(""), hash64("a"));
    }

    #[test]
    fn unaligned_tails_differ() {
        // 8/4/2/1-byte chunking must still see every byte.
        for len in 0..=17usize {
            let a: Vec<u8> = (0..len as u8).collect();
            let mut b = a.clone();
            if let Some(last) = b.last_mut() {
                *last ^= 0xff;
                let mut ha = FxHasher::default();
                ha.write(&a);
                let mut hb = FxHasher::default();
                hb.write(&b);
                assert_ne!(ha.finish(), hb.finish(), "len {len}");
            }
        }
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("a".into(), 1);
        m.insert("b".into(), 2);
        assert_eq!(m.get("a"), Some(&1));
        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(42);
        assert!(s.contains(&42));
    }

    #[test]
    fn high_bits_spread() {
        // The sharded cache selects shards by the high bits; sequential
        // keys must not all land in one shard.
        use std::collections::HashSet;
        let shards: HashSet<u64> = (0..64u32).map(|i| hash64(&i) >> 60).collect();
        assert!(shards.len() >= 8, "only {} of 16 shards used", shards.len());
    }
}
